//! CPU implementations of the non-convolution graph operators.
//!
//! Same contract as the convolution substrate
//! ([`CpuImpl::run_in`](crate::cpuref::CpuImpl::run_in)): every function
//! writes into a caller-provided output slice (fully overwritten) and
//! allocates nothing — the activation buffers come from the plan's
//! arena ([`crate::net::NetPlan`]), so the steady-state forward pass is
//! allocation-free end to end. Inputs are NCHW with the batch dimension
//! explicit (`n` items of `shape` each).

use crate::net::graph::{FeatShape, Pool2d};

/// Add a per-channel bias to an NCHW activation in place, optionally
/// followed by ReLU — the convolution epilogue (`out` is `n` items of
/// `m·plane` values; `bias` has `m` entries).
pub fn bias_relu_inplace(out: &mut [f32], m: usize, plane: usize, bias: &[f32], relu: bool) {
    assert_eq!(bias.len(), m, "bias/channel mismatch");
    assert_eq!(out.len() % (m * plane).max(1), 0, "output not whole items");
    for (ch, row) in out.chunks_exact_mut(plane).enumerate() {
        let b = bias[ch % m];
        if relu {
            for v in row.iter_mut() {
                *v = (*v + b).max(0.0);
            }
        } else {
            for v in row.iter_mut() {
                *v += b;
            }
        }
    }
}

/// The convolution epilogue on a **blocked NCHWc** activation (the
/// carrier is `[n][M/c][h·w][c]`, `c =`
/// [`CHANNEL_BLOCK`](crate::cpuref::pack::CHANNEL_BLOCK)): per-channel
/// bias + optional ReLU, applied lane-wise. Channel-tail padding lanes
/// (`m % c != 0`) are left untouched — they are zero by the blocked
/// kernel's contract and must stay zero, not pick up a bias.
/// Element-for-element the arithmetic is identical to
/// [`bias_relu_inplace`] on the plain layout, so blocked and plain
/// forwards stay bit-identical.
pub fn bias_relu_nchwc_inplace(
    out: &mut [f32],
    m: usize,
    plane: usize,
    bias: &[f32],
    relu: bool,
) {
    use crate::cpuref::pack::{blocked_channels, CHANNEL_BLOCK};
    assert_eq!(bias.len(), m, "bias/channel mismatch");
    let l = CHANNEL_BLOCK;
    let mblocks = blocked_channels(m) / l;
    assert_eq!(out.len() % (mblocks * plane * l).max(1), 0, "output not whole items");
    for (i, chunk) in out.chunks_exact_mut(plane * l).enumerate() {
        let base = (i % mblocks) * l;
        let lanes = l.min(m - base);
        for px in chunk.chunks_exact_mut(l) {
            for (lane, v) in px.iter_mut().take(lanes).enumerate() {
                let b = bias[base + lane];
                *v = if relu { (*v + b).max(0.0) } else { *v + b };
            }
        }
    }
}

/// Max pooling over `k×k` windows (NEG_INFINITY-initialized, so padding
/// cells never win).
pub fn max_pool_into(input: &[f32], n: usize, shape: FeatShape, p: Pool2d, out: &mut [f32]) {
    pool_into(input, n, shape, p, out, true)
}

/// Average pooling over `k×k` windows. Padding cells are excluded from
/// the divisor (equivalent to include-pad for the unpadded global pools
/// the zoo networks use).
pub fn avg_pool_into(input: &[f32], n: usize, shape: FeatShape, p: Pool2d, out: &mut [f32]) {
    pool_into(input, n, shape, p, out, false)
}

fn pool_into(
    input: &[f32],
    n: usize,
    shape: FeatShape,
    p: Pool2d,
    out: &mut [f32],
    is_max: bool,
) {
    if is_max {
        pool_planes::<true>(input, n, shape, p, out);
    } else {
        pool_planes::<false>(input, n, shape, p, out);
    }
}

/// Pooling skeleton, monomorphized per mode so the max path pays no
/// sum/count bookkeeping and the avg path no comparisons (the `MAX`
/// branches are compile-time constants). One output plane reads one
/// input plane — pooling never mixes channels or items.
fn pool_planes<const MAX: bool>(
    input: &[f32],
    n: usize,
    shape: FeatShape,
    p: Pool2d,
    out: &mut [f32],
) {
    let (h, w) = (shape.h, shape.w);
    let oh = (h + 2 * p.pad - p.k) / p.stride + 1;
    let ow = (w + 2 * p.pad - p.k) / p.stride + 1;
    assert_eq!(input.len(), n * shape.elems(), "pool input mismatch");
    assert_eq!(out.len(), n * shape.c * oh * ow, "pool output mismatch");
    for (q, orow) in out.chunks_exact_mut(oh * ow).enumerate() {
        let iplane = &input[q * h * w..(q + 1) * h * w];
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = if MAX { f32::NEG_INFINITY } else { 0.0 };
                let mut count = 0usize;
                for ky in 0..p.k {
                    let iy = (oy * p.stride + ky) as isize - p.pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..p.k {
                        let ix = (ox * p.stride + kx) as isize - p.pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let v = iplane[iy as usize * w + ix as usize];
                        if MAX {
                            acc = acc.max(v);
                        } else {
                            acc += v;
                            count += 1;
                        }
                    }
                }
                orow[oy * ow + ox] = if MAX { acc } else { acc / count as f32 };
            }
        }
    }
}

/// Copy one concat part into its channel band of the output: `src` is
/// `n` items of `c_part·plane` values, written at channel offset
/// `c_off` of an output with `c_total` channels. Callers invoke this
/// once per input, walking `c_off` — no gather list is built, so a
/// concat node allocates nothing.
pub fn concat_part_into(
    src: &[f32],
    n: usize,
    plane: usize,
    (c_part, c_off, c_total): (usize, usize, usize),
    out: &mut [f32],
) {
    assert_eq!(src.len(), n * c_part * plane, "concat part mismatch");
    assert_eq!(out.len(), n * c_total * plane, "concat output mismatch");
    assert!(c_off + c_part <= c_total, "concat band out of range");
    let part_len = c_part * plane;
    for item in 0..n {
        let dst = (item * c_total + c_off) * plane;
        out[dst..dst + part_len].copy_from_slice(&src[item * part_len..(item + 1) * part_len]);
    }
}

/// `out = a + b`, optionally followed by ReLU (the ResNet block join).
pub fn residual_add_into(a: &[f32], b: &[f32], relu: bool, out: &mut [f32]) {
    assert_eq!(a.len(), b.len(), "residual operand mismatch");
    assert_eq!(a.len(), out.len(), "residual output mismatch");
    for ((o, &x), &y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
        let v = x + y;
        *o = if relu { v.max(0.0) } else { v };
    }
}

/// Weights of a fully connected layer. The matrix is stored
/// **transposed** (`[in, out]` row-major) so the forward pass is a
/// plain row-major GEMM `out[n, out] = x[n, in] · wt[in, out]` on
/// [`sgemm`](crate::cpuref::gemm::sgemm) with no per-call transpose.
#[derive(Debug, Clone)]
pub struct LinearWeights {
    pub in_f: usize,
    pub out_f: usize,
    /// `[in_f, out_f]` row-major (transposed from the conventional
    /// `[out, in]`).
    pub wt: Vec<f32>,
    pub bias: Vec<f32>,
}

/// Fully connected layer over flattened inputs: `n` items of `in_f`
/// values → `n` items of `out_f`, plus bias and optional ReLU.
pub fn linear_into(input: &[f32], n: usize, lw: &LinearWeights, relu: bool, out: &mut [f32]) {
    assert_eq!(input.len(), n * lw.in_f, "linear input mismatch");
    assert_eq!(out.len(), n * lw.out_f, "linear output mismatch");
    assert_eq!(lw.wt.len(), lw.in_f * lw.out_f, "linear weight mismatch");
    out.fill(0.0); // sgemm accumulates
    crate::cpuref::gemm::sgemm(
        n,
        lw.in_f,
        lw.out_f,
        input,
        &lw.wt,
        out,
        crate::cpuref::gemm::default_threads(),
    );
    bias_relu_inplace(out, lw.out_f, 1, &lw.bias, relu);
}

/// Row-wise softmax: `n` items of `classes` logits → probabilities.
/// Max-subtracted for numerical stability.
pub fn softmax_into(input: &[f32], n: usize, classes: usize, out: &mut [f32]) {
    assert_eq!(input.len(), n * classes, "softmax input mismatch");
    assert_eq!(out.len(), n * classes, "softmax output mismatch");
    for (row_in, row_out) in
        input.chunks_exact(classes).zip(out.chunks_exact_mut(classes))
    {
        let max = row_in.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (o, &v) in row_out.iter_mut().zip(row_in.iter()) {
            let e = (v - max).exp();
            *o = e;
            sum += e;
        }
        for o in row_out.iter_mut() {
            *o /= sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand(rng: &mut Rng, len: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        rng.fill_uniform(&mut v, -1.0, 1.0);
        v
    }

    /// Brute-force pooling oracle, written independently of the
    /// plane-sliced implementation above.
    fn pool_oracle(
        input: &[f32],
        n: usize,
        s: FeatShape,
        p: Pool2d,
        is_max: bool,
    ) -> Vec<f32> {
        let oh = (s.h + 2 * p.pad - p.k) / p.stride + 1;
        let ow = (s.w + 2 * p.pad - p.k) / p.stride + 1;
        let mut out = vec![0.0f32; n * s.c * oh * ow];
        for item in 0..n {
            for c in 0..s.c {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut vals = Vec::new();
                        for ky in 0..p.k {
                            for kx in 0..p.k {
                                let iy = (oy * p.stride + ky) as isize - p.pad as isize;
                                let ix = (ox * p.stride + kx) as isize - p.pad as isize;
                                if iy >= 0
                                    && iy < s.h as isize
                                    && ix >= 0
                                    && ix < s.w as isize
                                {
                                    vals.push(
                                        input[((item * s.c + c) * s.h + iy as usize) * s.w
                                            + ix as usize],
                                    );
                                }
                            }
                        }
                        out[((item * s.c + c) * oh + oy) * ow + ox] = if is_max {
                            vals.iter().copied().fold(f32::NEG_INFINITY, f32::max)
                        } else {
                            vals.iter().sum::<f32>() / vals.len() as f32
                        };
                    }
                }
            }
        }
        out
    }

    #[test]
    fn pooling_matches_bruteforce_oracle() {
        let mut rng = Rng::new(0x9001);
        for (s, p) in [
            (FeatShape::new(3, 7, 7), Pool2d { k: 3, stride: 2, pad: 0 }),
            (FeatShape::new(2, 8, 8), Pool2d { k: 3, stride: 2, pad: 1 }),
            (FeatShape::new(4, 5, 5), Pool2d { k: 2, stride: 2, pad: 0 }),
            (FeatShape::new(1, 6, 6), Pool2d { k: 3, stride: 1, pad: 1 }),
            (FeatShape::new(5, 4, 4), Pool2d { k: 4, stride: 1, pad: 0 }), // global
        ] {
            for n in [1usize, 3] {
                let input = rand(&mut rng, n * s.elems());
                let oh = (s.h + 2 * p.pad - p.k) / p.stride + 1;
                let mut got = vec![0.0f32; n * s.c * oh * oh];
                max_pool_into(&input, n, s, p, &mut got);
                assert_eq!(got, pool_oracle(&input, n, s, p, true), "max {s} {p:?}");
                avg_pool_into(&input, n, s, p, &mut got);
                let want = pool_oracle(&input, n, s, p, false);
                for (g, w) in got.iter().zip(want.iter()) {
                    assert!((g - w).abs() < 1e-6, "avg {s} {p:?}");
                }
            }
        }
    }

    #[test]
    fn global_avg_pool_is_plane_mean() {
        let s = FeatShape::new(2, 3, 3);
        let input: Vec<f32> = (0..18).map(|v| v as f32).collect();
        let mut out = vec![0.0f32; 2];
        avg_pool_into(&input, 1, s, Pool2d { k: 3, stride: 1, pad: 0 }, &mut out);
        assert_eq!(out, vec![4.0, 13.0]); // means of 0..9 and 9..18
    }

    #[test]
    fn bias_relu_applies_per_channel() {
        // 2 items x 2 channels x 3-pixel planes.
        let mut out = vec![
            1.0, -1.0, 0.5, /* c0 */ 2.0, -2.0, 0.0, /* c1 */
            -0.5, 0.0, 3.0, /* c0 */ 1.0, 1.0, 1.0, /* c1 */
        ];
        bias_relu_inplace(&mut out, 2, 3, &[0.25, -1.0], true);
        assert_eq!(
            out,
            vec![1.25, 0.0, 0.75, 1.0, 0.0, 0.0, 0.0, 0.25, 3.25, 0.0, 0.0, 0.0]
        );
        // Without relu: plain add.
        let mut out = vec![1.0, -1.0];
        bias_relu_inplace(&mut out, 2, 1, &[1.0, 1.0], false);
        assert_eq!(out, vec![2.0, 0.0]);
    }

    /// The blocked epilogue is the plain epilogue viewed through the
    /// NCHWc packing, bit for bit, and never touches channel-tail
    /// padding lanes (m % CHANNEL_BLOCK != 0 exercises the tail).
    #[test]
    fn blocked_bias_relu_matches_plain_through_the_packing() {
        use crate::cpuref::pack::{nchw_to_nchwc, nchwc_elems, nchwc_to_nchw};
        let mut rng = Rng::new(0xB1A5);
        for &(n, m, h, w, relu) in &[
            (2usize, 5usize, 3usize, 4usize, true),
            (1, 8, 2, 2, false),
            (3, 11, 1, 3, true),
        ] {
            let plane = h * w;
            let mut plain = rand(&mut rng, n * m * plane);
            let mut bias = vec![0.0f32; m];
            rng.fill_uniform(&mut bias, -0.5, 0.5);
            let mut blocked = vec![0.0f32; nchwc_elems(n, m, h, w)];
            nchw_to_nchwc(n, m, h, w, &plain, &mut blocked);

            bias_relu_inplace(&mut plain, m, plane, &bias, relu);
            bias_relu_nchwc_inplace(&mut blocked, m, plane, &bias, relu);

            let mut back = vec![0.0f32; n * m * plane];
            nchwc_to_nchw(n, m, h, w, &blocked, &mut back);
            assert_eq!(back, plain, "n={n} m={m} relu={relu}");
            // Padding lanes stayed exactly zero.
            let l = crate::cpuref::pack::CHANNEL_BLOCK;
            let mblocks = blocked.len() / (n * plane * l);
            for (i, chunk) in blocked.chunks_exact(plane * l).enumerate() {
                let base = (i % mblocks) * l;
                for px in chunk.chunks_exact(l) {
                    for (lane, &v) in px.iter().enumerate() {
                        if base + lane >= m {
                            assert_eq!(v, 0.0, "padding lane picked up bias");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn concat_places_bands_per_item() {
        // Two parts (c=1 and c=2) over 2 items of 2-pixel planes.
        let a = vec![1.0, 2.0, /* item1 */ 10.0, 20.0];
        let b = vec![3.0, 4.0, 5.0, 6.0, /* item1 */ 30.0, 40.0, 50.0, 60.0];
        let mut out = vec![0.0f32; 2 * 3 * 2];
        concat_part_into(&a, 2, 2, (1, 0, 3), &mut out);
        concat_part_into(&b, 2, 2, (2, 1, 3), &mut out);
        assert_eq!(
            out,
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 10.0, 20.0, 30.0, 40.0, 50.0, 60.0]
        );
    }

    #[test]
    fn residual_add_matches_elementwise() {
        let a = vec![1.0, -2.0, 3.0];
        let b = vec![0.5, 1.0, -4.0];
        let mut out = vec![0.0f32; 3];
        residual_add_into(&a, &b, false, &mut out);
        assert_eq!(out, vec![1.5, -1.0, -1.0]);
        residual_add_into(&a, &b, true, &mut out);
        assert_eq!(out, vec![1.5, 0.0, 0.0]);
    }

    #[test]
    fn linear_matches_bruteforce_oracle() {
        let mut rng = Rng::new(0x9002);
        let (n, in_f, out_f) = (3usize, 11usize, 7usize);
        let input = rand(&mut rng, n * in_f);
        let lw = LinearWeights {
            in_f,
            out_f,
            wt: rand(&mut rng, in_f * out_f),
            bias: rand(&mut rng, out_f),
        };
        let mut got = vec![0.0f32; n * out_f];
        linear_into(&input, n, &lw, false, &mut got);
        for item in 0..n {
            for o in 0..out_f {
                let mut want = lw.bias[o];
                for i in 0..in_f {
                    want += input[item * in_f + i] * lw.wt[i * out_f + o];
                }
                let g = got[item * out_f + o];
                assert!((g - want).abs() < 1e-4, "({item},{o}): {g} vs {want}");
            }
        }
        // ReLU clamps the negative entries.
        let mut relued = vec![0.0f32; n * out_f];
        linear_into(&input, n, &lw, true, &mut relued);
        for (r, g) in relued.iter().zip(got.iter()) {
            assert_eq!(*r, g.max(0.0));
        }
    }

    #[test]
    fn softmax_rows_are_distributions() {
        let mut rng = Rng::new(0x9003);
        let (n, classes) = (4usize, 9usize);
        let input = rand(&mut rng, n * classes);
        let mut out = vec![0.0f32; n * classes];
        softmax_into(&input, n, classes, &mut out);
        for row in out.chunks_exact(classes) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&p| p > 0.0 && p < 1.0));
        }
        // Ordering preserved: argmax of logits == argmax of probs.
        for (lrow, prow) in input.chunks_exact(classes).zip(out.chunks_exact(classes)) {
            let am = |r: &[f32]| {
                r.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            };
            assert_eq!(am(lrow), am(prow));
        }
        // Large logits do not overflow (max-subtraction).
        let big = vec![1000.0f32, 1001.0, 999.0];
        let mut o = vec![0.0f32; 3];
        softmax_into(&big, 1, 3, &mut o);
        assert!(o.iter().all(|p| p.is_finite()));
        assert!((o.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }
}
