//! [`NetPlanner`]: compile a [`NetGraph`] into an executable
//! [`NetPlan`] for a [`Backend`] — per-conv algorithm choice, liveness
//! analysis, and a slot arena that makes the steady-state forward pass
//! allocation-free end to end.
//!
//! This extends PR 2's per-convolution contract (plan once, execute
//! many into caller-owned buffers) to a whole network:
//!
//! * **Algorithm choice** — every conv node gets its own
//!   [`ConvPlan`] via [`algo_get`] (heuristic, instant) or
//!   [`algo_find`] (exhaustive, timed on the backend) — the paper's
//!   §4.1 deployment story ("frameworks automatically select the best
//!   performing convolution algorithm for each layer") applied to a
//!   runnable graph rather than a census list.
//! * **Liveness + arena** — node outputs are assigned to a small set of
//!   reusable buffer *slots* by a linear scan over the topological
//!   order: a slot is freed once its value's last consumer has run and
//!   is then reused (best-fit) by later nodes. A chain of layers
//!   ping-pongs between two slots; inception/residual branches hold as
//!   many slots as values are simultaneously live. All slots are
//!   allocated to their high-water size at compile time.
//! * **Layout lowering** — after algorithms are chosen, a layout pass
//!   under the planner's [`LayoutPolicy`] rewrites the graph so convs
//!   running the cuConv algorithm consume and produce blocked NCHWc
//!   activations: [`Op::LayoutConvert`] edges are inserted only where
//!   the layout actually changes and back-to-back pairs are elided, so
//!   a chain of blocked convs runs blocked end to end with one ingress
//!   and one egress convert and none interior.
//! * **One shared workspace** — conv scratch comes from a single
//!   [`Workspace`] pre-grown to the *maximum* per-layer requirement
//!   (layers run sequentially, so the workspace ping-pongs too), still
//!   under the paper's 1 GB cap per layer.
//!
//! At execute time ([`NetPlan::forward_into`]) the only per-request
//! buffer is the caller's output slice: activations live in the arena,
//! conv scratch in the workspace, weights in the plan.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use crate::algo::{Algorithm, AutotuneResult};
use crate::backend::{
    algo_find, algo_find_cached, algo_get, Backend, ConvDescriptor, ConvPlan, LayoutPolicy,
    TensorLayout, Workspace,
};
use crate::conv::{ConvSpec, F32_BYTES};
use crate::cpuref::pack::{blocked_channels, nchw_to_nchwc, nchwc_to_nchw};
use crate::net::graph::{FeatShape, NetGraph, Node, NodeId, Op};
use crate::net::ops;
use crate::net::ops::LinearWeights;
use crate::tensor::Tensor;
use crate::tunecache::TuneCache;
use crate::util::rng::Rng;

/// How the planner picks each conv node's algorithm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoChoice {
    /// [`algo_get`] per layer — instant, the `cudnnGet` analogue.
    Heuristic,
    /// [`algo_find`] per layer with this many timed iterations — the
    /// `cudnnFind` analogue, slow at compile time (every supported
    /// algorithm runs on every layer shape), fastest at serve time.
    Measured { iters: usize },
}

/// Fixed weight seed: plans for the same graph are identical across
/// processes and batch sizes (the batcher must not change outputs).
const WEIGHT_SEED: u64 = 0x0CF5_EED5;

/// Bias init range (weights use He-style bounds; see `he_bound`).
const BIAS_RANGE: f32 = 0.1;

/// He-uniform bound for `fan_in` inputs: keeps activation magnitudes
/// roughly constant through arbitrarily deep ReLU stacks, so a
/// 50-layer forward of seeded weights neither explodes nor vanishes.
fn he_bound(fan_in: usize) -> f32 {
    (6.0 / fan_in as f64).sqrt() as f32
}

/// The [`ConvSpec`] of a conv node applied to input shape `x` at a
/// batch size.
fn conv_spec(
    x: FeatShape,
    m: usize,
    k: usize,
    stride: usize,
    pad: usize,
    batch: usize,
) -> ConvSpec {
    ConvSpec {
        n: batch,
        c: x.c,
        h: x.h,
        w: x.w,
        m,
        kh: k,
        kw: k,
        stride,
        pad_h: pad,
        pad_w: pad,
    }
}

/// Per-item element count of a value's in-memory **carrier** (what an
/// arena slot must hold): plain values store `c·h·w`, blocked values
/// the channel-padded `blocked_channels(c)·h·w`.
fn carrier_elems(shape: FeatShape, layout: TensorLayout) -> usize {
    match layout {
        TensorLayout::Nchw => shape.elems(),
        TensorLayout::Nchwc => blocked_channels(shape.c) * shape.h * shape.w,
    }
}

/// Compiles graphs against one backend.
pub struct NetPlanner {
    backend: Box<dyn Backend>,
    choice: AlgoChoice,
    /// Activation-layout policy for the lowering pass (see
    /// [`NetPlanner::with_layout`]).
    layout: LayoutPolicy,
    /// Persistent tune cache, when attached: [`AlgoChoice::Measured`]
    /// searches consult it before timing (a hit replays a recorded
    /// ranking with zero measurements) and record fresh rankings into
    /// it — `compile_for_sizes` over a cached network becomes a pure
    /// replay of the whole profile.
    tune_cache: Option<Arc<TuneCache>>,
}

impl NetPlanner {
    pub fn new(backend: Box<dyn Backend>) -> NetPlanner {
        NetPlanner {
            backend,
            choice: AlgoChoice::Heuristic,
            layout: LayoutPolicy::default(),
            tune_cache: None,
        }
    }

    pub fn with_choice(mut self, choice: AlgoChoice) -> NetPlanner {
        self.choice = choice;
        self
    }

    /// Set the activation-layout policy the compile-time lowering pass
    /// follows. The default, [`LayoutPolicy::Auto`], runs a conv on
    /// blocked NCHWc activations exactly when its chosen algorithm is
    /// cuConv and the backend supports the layout;
    /// [`LayoutPolicy::Nchwc`] forces cuConv + blocked on every conv
    /// the backend can run that way; [`LayoutPolicy::Nchw`] disables
    /// the blocked path entirely (pre-layout plans, bit for bit).
    pub fn with_layout(mut self, layout: LayoutPolicy) -> NetPlanner {
        self.layout = layout;
        self
    }

    /// The activation-layout policy this planner lowers under.
    pub fn layout_policy(&self) -> LayoutPolicy {
        self.layout
    }

    /// Attach a persistent [`TuneCache`] for measured algorithm
    /// searches. Share the same `Arc` with the backend's
    /// [`with_tune_cache`](crate::backend::CpuRefBackend::with_tune_cache)
    /// so tile picks land in the same file.
    pub fn with_tune_cache(mut self, cache: Arc<TuneCache>) -> NetPlanner {
        self.tune_cache = Some(cache);
        self
    }

    /// [`algo_find`], routed through the tune cache when one is
    /// attached.
    fn find(&self, desc: &ConvDescriptor, iters: usize) -> AutotuneResult {
        match &self.tune_cache {
            Some(cache) => algo_find_cached(self.backend.as_ref(), desc, iters, cache),
            None => algo_find(self.backend.as_ref(), desc, iters),
        }
    }

    /// The backend plans compiled by this planner execute on.
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    pub fn into_backend(self) -> Box<dyn Backend> {
        self.backend
    }

    /// The planner's per-conv algorithm choice, always made on a plain
    /// NCHW descriptor — layout lowering runs *after* this, so
    /// tune-cache keys and measured rankings are identical whatever the
    /// layout policy (a warm cache replays the same choices, then the
    /// same lowering).
    fn choose(&self, desc: &ConvDescriptor) -> Result<Algorithm> {
        match self.choice {
            AlgoChoice::Heuristic => algo_get(self.backend.as_ref(), desc),
            AlgoChoice::Measured { iters } => match self.find(desc, iters).best() {
                Some(e) => Ok(e.algo),
                None => algo_get(self.backend.as_ref(), desc),
            },
        }
    }

    /// Compile `graph` at a fixed batch size: type-check, choose a
    /// per-conv algorithm, lower layouts under the planner's
    /// [`LayoutPolicy`], materialize seeded weights, run liveness
    /// analysis and allocate the activation arena + shared workspace.
    pub fn compile(&self, graph: &NetGraph, batch: usize) -> Result<NetPlan> {
        ensure!(batch >= 1, "batch must be at least 1");
        let shapes = graph.infer_shapes()?;
        let mut algos: Vec<Option<Algorithm>> = vec![None; graph.len()];
        for (id, node) in graph.nodes().iter().enumerate() {
            if let Op::Conv { m, k, stride, pad, .. } = &node.op {
                let spec =
                    conv_spec(shapes[node.inputs[0]], *m, *k, *stride, *pad, batch);
                algos[id] = Some(self.choose(&ConvDescriptor::new(spec)?)?);
            }
        }
        let lowered = self.lower(graph, &shapes, algos, &[batch])?;
        self.compile_lowered(&lowered, batch, None)
    }

    /// Compile one plan per batch size with a **single** algorithm per
    /// conv node across all of them (chosen like [`compile`], then
    /// narrowed to the candidates the backend supports at *every*
    /// size) — so identical pixels produce identical outputs no matter
    /// how a serving batcher groups requests, the same contract as
    /// `ConvBackendRunner`. Returns `(batch, plan)` pairs, ascending.
    ///
    /// [`compile`]: NetPlanner::compile
    pub fn compile_for_sizes(
        &self,
        graph: &NetGraph,
        sizes: &[usize],
    ) -> Result<Vec<(usize, NetPlan)>> {
        let mut sizes: Vec<usize> = sizes.to_vec();
        sizes.sort_unstable();
        sizes.dedup();
        ensure!(!sizes.is_empty() && sizes[0] >= 1, "need at least one batch size >= 1");
        let shapes = graph.infer_shapes()?;
        let backend = self.backend.as_ref();
        let mut pins: Vec<Option<Algorithm>> = vec![None; graph.len()];
        for (id, node) in graph.nodes().iter().enumerate() {
            if let Op::Conv { m, k, stride, pad, .. } = &node.op {
                let base =
                    conv_spec(shapes[node.inputs[0]], *m, *k, *stride, *pad, sizes[0]);
                let desc = ConvDescriptor::new(base)?;
                // Candidates in preference order: the planner's choice
                // policy first (timed ranking for Measured, heuristic
                // pick otherwise), then everything else the backend
                // supports at the base size.
                let mut candidates = match self.choice {
                    AlgoChoice::Heuristic => Vec::new(),
                    AlgoChoice::Measured { iters } => {
                        self.find(&desc, iters).entries.iter().map(|e| e.algo).collect()
                    }
                };
                candidates.push(algo_get(backend, &desc)?);
                candidates.extend(backend.supported_algorithms(&base));
                let algo = candidates
                    .into_iter()
                    .find(|&a| {
                        sizes.iter().all(|&b| {
                            backend.capabilities(&base.with_batch(b), a).is_supported()
                        })
                    })
                    .ok_or_else(|| {
                        anyhow!(
                            "backend '{}' supports no single algorithm across batch \
                             sizes {sizes:?} for conv node '{}'",
                            backend.name(),
                            node.name
                        )
                    })?;
                pins[id] = Some(algo);
            }
        }
        // Lower layouts once (the pass sees every batch size, so a conv
        // goes blocked only if cuConv runs at all of them), then share
        // one weight set across every batch size. Convert nodes draw no
        // parameters, so the lowered graph's seeded weight stream is
        // identical to the original's.
        let lowered = self.lower(graph, &shapes, pins, &sizes)?;
        let params = draw_params(&lowered.graph, &lowered.shapes);
        sizes
            .iter()
            .map(|&b| {
                self.compile_lowered(&lowered, b, Some(&params)).map(|p| (b, p))
            })
            .collect()
    }

    /// The layout pass: decide which convs run blocked (NCHWc) under
    /// the planner's [`LayoutPolicy`], then rewrite the graph so every
    /// blocked conv consumes and produces blocked values.
    /// [`Op::LayoutConvert`] edges are emitted only where the layout
    /// actually changes, cached per `(value, layout)` so a value is
    /// converted at most once per direction, and a convert back to a
    /// value's own layout resolves to the value itself — back-to-back
    /// pairs are elided by construction, so a chain of blocked convs
    /// runs with one ingress and one egress convert and none interior.
    ///
    /// Algorithm choice happens *before* this pass, on plain NCHW
    /// descriptors; under [`LayoutPolicy::Auto`] a conv goes blocked
    /// exactly when that choice picked cuConv (the backend permitting),
    /// and [`LayoutPolicy::Nchwc`] overrides the choice to cuConv
    /// wherever the backend can run it at every batch size in `sizes`.
    fn lower(
        &self,
        graph: &NetGraph,
        shapes: &[FeatShape],
        mut algos: Vec<Option<Algorithm>>,
        sizes: &[usize],
    ) -> Result<Lowered> {
        let backend = self.backend.as_ref();
        let mut blocked = vec![false; graph.len()];
        if self.layout != LayoutPolicy::Nchw
            && backend.supports_layout(TensorLayout::Nchwc)
        {
            for (id, node) in graph.nodes().iter().enumerate() {
                let Op::Conv { m, k, stride, pad, .. } = &node.op else { continue };
                let cuconv_everywhere = sizes.iter().all(|&b| {
                    let spec =
                        conv_spec(shapes[node.inputs[0]], *m, *k, *stride, *pad, b);
                    backend.capabilities(&spec, Algorithm::CuConv).is_supported()
                });
                if !cuconv_everywhere {
                    continue;
                }
                match self.layout {
                    LayoutPolicy::Auto => {
                        blocked[id] = algos[id] == Some(Algorithm::CuConv);
                    }
                    LayoutPolicy::Nchwc => {
                        algos[id] = Some(Algorithm::CuConv);
                        blocked[id] = true;
                    }
                    LayoutPolicy::Nchw => unreachable!("guarded above"),
                }
            }
        }
        let has_convert =
            graph.nodes().iter().any(|n| matches!(n.op, Op::LayoutConvert { .. }));
        if !has_convert && !blocked.iter().any(|&b| b) {
            // Nothing to rewrite: pre-layout plans, node ids unchanged.
            return Ok(Lowered {
                graph: graph.clone(),
                shapes: shapes.to_vec(),
                layouts: vec![TensorLayout::Nchw; graph.len()],
                algos,
            });
        }

        let mut rw = Rewrite {
            nodes: Vec::with_capacity(graph.len() + 4),
            layouts: Vec::with_capacity(graph.len() + 4),
            algos: Vec::with_capacity(graph.len() + 4),
            converted: HashMap::new(),
        };
        // Original node id -> lowered id of its value (in the layout
        // the lowered producer emits).
        let mut map: Vec<NodeId> = Vec::with_capacity(graph.len());
        for (id, node) in graph.nodes().iter().enumerate() {
            let lowered = match &node.op {
                // A pre-existing convert collapses onto the requested
                // value — reusing a cached conversion or the original
                // value itself (pair elision). Under the Nchw policy
                // explicit blocked requests are rewritten away.
                Op::LayoutConvert { to } => {
                    let want = match self.layout {
                        LayoutPolicy::Nchw => TensorLayout::Nchw,
                        _ => *to,
                    };
                    rw.value_in(map[node.inputs[0]], want)
                }
                _ => {
                    let want = if blocked[id] {
                        TensorLayout::Nchwc
                    } else {
                        TensorLayout::Nchw
                    };
                    let inputs: Vec<NodeId> = node
                        .inputs
                        .iter()
                        .map(|&s| rw.value_in(map[s], want))
                        .collect();
                    rw.emit(
                        Node { name: node.name.clone(), op: node.op.clone(), inputs },
                        want,
                        algos[id],
                    )
                }
            };
            map.push(lowered);
        }
        // Egress: the network output is plain NCHW at the graph tail.
        let out = rw.value_in(map[graph.output_id()], TensorLayout::Nchw);
        if out + 1 != rw.nodes.len() {
            // Rare: the output collapsed onto an interior value (the
            // original graph ended in a redundant convert). The output
            // must be the last node, so materialize a copy-through.
            let name = format!("{}.out", rw.nodes[out].name);
            rw.emit(
                Node {
                    name,
                    op: Op::LayoutConvert { to: TensorLayout::Nchw },
                    inputs: vec![out],
                },
                TensorLayout::Nchw,
                None,
            );
        }
        let graph = NetGraph::from_parts(graph.name.clone(), rw.nodes);
        let shapes = graph.infer_shapes()?;
        Ok(Lowered { graph, shapes, layouts: rw.layouts, algos: rw.algos })
    }

    fn compile_lowered(
        &self,
        lowered: &Lowered,
        batch: usize,
        shared_params: Option<&[NodeParams]>,
    ) -> Result<NetPlan> {
        ensure!(batch >= 1, "batch must be at least 1");
        let Lowered { graph, shapes, layouts, algos } = lowered;
        let backend = self.backend.as_ref();
        let params = match shared_params {
            Some(p) => p.to_vec(), // clones Arcs, not weights
            None => draw_params(graph, shapes),
        };

        // Per-node resources: conv plans + the seeded weights (weight
        // draws depend only on the graph, never on batch or algorithm,
        // so every batch size serves the same function).
        let mut steps = Vec::with_capacity(graph.len());
        let mut max_ws_bytes = 0usize;
        for ((id, node), param) in graph.nodes().iter().enumerate().zip(params) {
            let step = match (&node.op, param) {
                (
                    Op::Conv { m, k, stride, pad, .. },
                    NodeParams::Conv { filters, bias },
                ) => {
                    let x = shapes[node.inputs[0]];
                    let spec = conv_spec(x, *m, *k, *stride, *pad, batch);
                    let desc = ConvDescriptor::new(spec)?.with_layout(layouts[id]);
                    let algo = algos[id].ok_or_else(|| {
                        anyhow!(
                            "conv node '{}' reached compile without an algorithm",
                            node.name
                        )
                    })?;
                    // Plan with the node's weights: the backend derives
                    // plan-owned state (packed tiled-cuConv panels) once
                    // here — and because the weights are Arc-shared
                    // across batch sizes and replicas, the backend's
                    // pack cache shares the derived state too.
                    let plan = backend.plan_with_filters(&desc, algo, &filters).map_err(
                        |e| e.context(format!("planning conv node '{}'", node.name)),
                    )?;
                    max_ws_bytes = max_ws_bytes.max(plan.workspace_bytes());
                    StepRes::Conv { plan, filters, bias }
                }
                (Op::Linear { .. }, NodeParams::Linear(lw)) => StepRes::Linear(lw),
                _ => StepRes::Plain,
            };
            steps.push(step);
        }

        // Liveness: a value dies after its last consumer; the network
        // output never dies.
        let mut last_use: Vec<usize> = (0..graph.len()).collect();
        for (id, node) in graph.nodes().iter().enumerate() {
            for &src in &node.inputs {
                last_use[src] = last_use[src].max(id);
            }
        }
        last_use[graph.output_id()] = graph.len();

        // Linear-scan slot assignment over the topological order.
        let mut slot_cap: Vec<usize> = Vec::new(); // elems, batch included
        let mut slot_of: Vec<usize> = vec![usize::MAX; graph.len()];
        let mut free: Vec<usize> = Vec::new();
        let mut released = vec![false; graph.len()];
        for id in 0..graph.len() {
            for v in 0..id {
                if !released[v] && last_use[v] < id {
                    released[v] = true;
                    free.push(slot_of[v]);
                }
            }
            let need = batch * carrier_elems(shapes[id], layouts[id]);
            // Best fit: the smallest free slot that already holds
            // `need`; otherwise the largest free slot (grows the least).
            let pick = free
                .iter()
                .enumerate()
                .filter(|(_, &s)| slot_cap[s] >= need)
                .min_by_key(|(_, &s)| slot_cap[s])
                .or_else(|| free.iter().enumerate().max_by_key(|(_, &s)| slot_cap[s]))
                .map(|(i, _)| i);
            let slot = match pick {
                Some(i) => free.swap_remove(i),
                None => {
                    slot_cap.push(0);
                    slot_cap.len() - 1
                }
            };
            slot_cap[slot] = slot_cap[slot].max(need);
            slot_of[id] = slot;
        }

        // Materialize the arena at its high water and pre-grow the
        // shared workspace: nothing below grows at execute time.
        let slots: Vec<Vec<f32>> =
            slot_cap.iter().map(|&cap| Vec::with_capacity(cap)).collect();
        let mut workspace = Workspace::new();
        workspace.ensure_bytes(max_ws_bytes)?;

        Ok(NetPlan {
            graph: graph.clone(),
            shapes: shapes.clone(),
            layouts: layouts.clone(),
            batch,
            backend_name: backend.name(),
            steps,
            slot_of,
            slots,
            planned_arena_elems: slot_cap.iter().sum(),
            max_ws_bytes,
            workspace,
            node_seconds: vec![0.0; graph.len()],
        })
    }
}

/// A graph after the layout pass: [`Op::LayoutConvert`] nodes inserted
/// around blocked convs (back-to-back pairs elided), with the carried
/// layout and pinned algorithm of every lowered node.
struct Lowered {
    graph: NetGraph,
    shapes: Vec<FeatShape>,
    layouts: Vec<TensorLayout>,
    algos: Vec<Option<Algorithm>>,
}

/// Working state of the layout rewrite in [`NetPlanner::lower`].
struct Rewrite {
    nodes: Vec<Node>,
    /// Layout of each lowered node's output value.
    layouts: Vec<TensorLayout>,
    /// Pinned algorithm of each lowered node (conv nodes only).
    algos: Vec<Option<Algorithm>>,
    /// `(lowered value, layout)` -> lowered id holding that value in
    /// that layout; both directions are recorded, which is what elides
    /// convert round-trips.
    converted: HashMap<(NodeId, TensorLayout), NodeId>,
}

impl Rewrite {
    fn emit(
        &mut self,
        node: Node,
        layout: TensorLayout,
        algo: Option<Algorithm>,
    ) -> NodeId {
        self.nodes.push(node);
        self.layouts.push(layout);
        self.algos.push(algo);
        self.nodes.len() - 1
    }

    /// The lowered id of `src`'s value in `want` layout, emitting a
    /// cached convert node only when the layouts actually differ.
    fn value_in(&mut self, src: NodeId, want: TensorLayout) -> NodeId {
        if self.layouts[src] == want {
            return src;
        }
        if let Some(&id) = self.converted.get(&(src, want)) {
            return id;
        }
        let name = format!("{}.{}", self.nodes[src].name, want);
        let from = self.layouts[src];
        let id = self.emit(
            Node { name, op: Op::LayoutConvert { to: want }, inputs: vec![src] },
            want,
            None,
        );
        self.converted.insert((src, want), id);
        // Converting the new value back to the source's layout is the
        // source itself — the reverse edge that elides round-trips.
        self.converted.insert((id, from), src);
        id
    }
}

/// Per-node execution resources. Weights are behind `Arc` so the
/// per-batch-size plans of [`NetPlanner::compile_for_sizes`] share one
/// copy (weights never depend on batch; VGG19's ~550 MB of parameters
/// must not be duplicated per serving batch size).
#[derive(Clone)]
enum StepRes {
    Plain,
    Conv { plan: ConvPlan, filters: Arc<Tensor>, bias: Arc<Vec<f32>> },
    Linear(Arc<LinearWeights>),
}

/// The seeded parameters of one node, drawn once per graph.
#[derive(Clone)]
enum NodeParams {
    None,
    Conv { filters: Arc<Tensor>, bias: Arc<Vec<f32>> },
    Linear(Arc<LinearWeights>),
}

/// Draw every node's seeded parameters (He-uniform weights, small
/// uniform biases) in node order from the fixed seed — a pure function
/// of the graph, shareable across batch sizes.
fn draw_params(graph: &NetGraph, shapes: &[FeatShape]) -> Vec<NodeParams> {
    let mut rng = Rng::new(WEIGHT_SEED);
    graph
        .nodes()
        .iter()
        .map(|node| match &node.op {
            Op::Conv { m, k, .. } => {
                let x = shapes[node.inputs[0]];
                let bound = he_bound(x.c * k * k);
                let filters = Tensor::random(*m, x.c, *k, *k, &mut rng, -bound, bound);
                let mut bias = vec![0.0f32; *m];
                rng.fill_uniform(&mut bias, -BIAS_RANGE, BIAS_RANGE);
                NodeParams::Conv { filters: Arc::new(filters), bias: Arc::new(bias) }
            }
            Op::Linear { out, .. } => {
                let in_f = shapes[node.inputs[0]].elems();
                let bound = he_bound(in_f);
                let mut wt = vec![0.0f32; in_f * out];
                rng.fill_uniform(&mut wt, -bound, bound);
                let mut bias = vec![0.0f32; *out];
                rng.fill_uniform(&mut bias, -BIAS_RANGE, BIAS_RANGE);
                NodeParams::Linear(Arc::new(LinearWeights {
                    in_f,
                    out_f: *out,
                    wt,
                    bias,
                }))
            }
            _ => NodeParams::None,
        })
        .collect()
}

/// Per-layer entry of [`NetPlan::layer_report`].
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    pub kind: &'static str,
    pub out_shape: FeatShape,
    /// Chosen algorithm (conv nodes only).
    pub algo: Option<Algorithm>,
    /// Workspace requirement of the conv plan (conv nodes only).
    pub workspace_bytes: usize,
    /// Wall-clock of this node in the most recent forward.
    pub seconds: f64,
}

/// A compiled, executable whole-network forward plan: conv plans and
/// seeded weights per node, the activation arena, and the shared conv
/// workspace. Compile once ([`NetPlanner::compile`]), forward many —
/// steady-state [`NetPlan::forward_into`] allocates no buffers.
pub struct NetPlan {
    graph: NetGraph,
    shapes: Vec<FeatShape>,
    /// Activation layout of each node's output value (aligned with
    /// `graph` node ids; the lowering pass decided these).
    layouts: Vec<TensorLayout>,
    batch: usize,
    backend_name: &'static str,
    steps: Vec<StepRes>,
    slot_of: Vec<usize>,
    slots: Vec<Vec<f32>>,
    planned_arena_elems: usize,
    max_ws_bytes: usize,
    workspace: Workspace,
    node_seconds: Vec<f64>,
}

impl NetPlan {
    pub fn graph(&self) -> &NetGraph {
        &self.graph
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Input f32s per forward (`batch × c·h·w`).
    pub fn input_elems(&self) -> usize {
        self.batch * self.shapes[0].elems()
    }

    /// Output f32s per forward (`batch × classes`).
    pub fn output_elems(&self) -> usize {
        self.batch * self.shapes[self.graph.output_id()].elems()
    }

    /// Classes of the network head (per-item output width).
    pub fn classes(&self) -> usize {
        self.shapes[self.graph.output_id()].elems()
    }

    /// Number of arena slots the liveness analysis produced (≪ nodes:
    /// chains ping-pong between two).
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Bytes the arena was planned to (sum of slot high-water sizes).
    pub fn planned_arena_bytes(&self) -> usize {
        self.planned_arena_elems * F32_BYTES
    }

    /// Bytes the arena actually holds — flat across forwards (the
    /// network-scope analogue of `Workspace::high_water_bytes`).
    pub fn arena_capacity_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.capacity() * F32_BYTES).sum()
    }

    /// Maximum per-layer conv workspace requirement (what the shared
    /// workspace was pre-grown to).
    pub fn max_conv_workspace_bytes(&self) -> usize {
        self.max_ws_bytes
    }

    /// The shared conv workspace (telemetry:
    /// [`Workspace::high_water_bytes`], [`Workspace::capacity_bytes`]).
    pub fn workspace(&self) -> &Workspace {
        &self.workspace
    }

    /// Activation layout of every node's output value, aligned with
    /// [`NetPlan::graph`] node ids (the lowered graph's, when the
    /// layout pass rewrote it).
    pub fn node_layouts(&self) -> &[TensorLayout] {
        &self.layouts
    }

    /// Number of `Layout::Convert` nodes the layout pass left in the
    /// graph — elision telemetry: a fully blocked chain has exactly one
    /// ingress and one egress convert, a plain plan zero.
    pub fn convert_count(&self) -> usize {
        self.graph
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, Op::LayoutConvert { .. }))
            .count()
    }

    /// Id of the node named `name` in this plan's (possibly lowered)
    /// graph. Builder names survive the layout rewrite unchanged;
    /// inserted converts get dotted suffixes, so lookups by original
    /// layer name stay unambiguous.
    pub fn node_id(&self, name: &str) -> Option<NodeId> {
        self.graph.nodes().iter().position(|n| n.name == name)
    }

    /// The algorithm planned for each conv node, in execution order.
    pub fn conv_algorithms(&self) -> Vec<(String, Algorithm)> {
        self.graph
            .nodes()
            .iter()
            .zip(self.steps.iter())
            .filter_map(|(node, step)| match step {
                StepRes::Conv { plan, .. } => Some((node.name.clone(), plan.algo())),
                _ => None,
            })
            .collect()
    }

    /// Seeded filters + bias of a conv node (verification harnesses).
    pub fn conv_params(&self, id: NodeId) -> Option<(&Tensor, &[f32])> {
        match &self.steps[id] {
            StepRes::Conv { filters, bias, .. } => {
                Some((filters.as_ref(), bias.as_slice()))
            }
            _ => None,
        }
    }

    /// The backend plan of a conv node (verification harnesses — e.g.
    /// pinning that packed weights are shared across batch sizes and
    /// replicas, not duplicated).
    pub fn conv_plan(&self, id: NodeId) -> Option<&ConvPlan> {
        match &self.steps[id] {
            StepRes::Conv { plan, .. } => Some(plan),
            _ => None,
        }
    }

    /// Per-layer breakdown of the most recent forward.
    pub fn layer_report(&self) -> Vec<LayerReport> {
        self.graph
            .nodes()
            .iter()
            .enumerate()
            .map(|(id, node)| {
                let (algo, ws) = match &self.steps[id] {
                    StepRes::Conv { plan, .. } => {
                        (Some(plan.algo()), plan.workspace_bytes())
                    }
                    _ => (None, 0),
                };
                LayerReport {
                    name: node.name.clone(),
                    kind: node.op.kind(),
                    out_shape: self.shapes[id],
                    algo,
                    workspace_bytes: ws,
                    seconds: self.node_seconds[id],
                }
            })
            .collect()
    }

    /// Seconds spent in conv nodes during the most recent forward.
    pub fn conv_seconds(&self) -> f64 {
        self.node_seconds
            .iter()
            .zip(self.steps.iter())
            .filter(|(_, s)| matches!(s, StepRes::Conv { .. }))
            .map(|(&t, _)| t)
            .sum()
    }

    /// Total seconds of the most recent forward.
    pub fn total_seconds(&self) -> f64 {
        self.node_seconds.iter().sum()
    }

    /// Cheap clone for sharded serving. The expensive compile products
    /// are **shared** via `Arc` — the seeded weights (VGG19's ~550 MB
    /// of parameters stays one copy no matter how many workers serve
    /// it) and each conv node's `ConvPlan` payload (same algorithm
    /// choices). Small metadata — graph, shapes, slot assignment — is
    /// plainly copied per replica. The replica **owns** a fresh
    /// activation arena and conv workspace, both pre-sized to the
    /// original's planned figures, plus fresh per-node timers. Every
    /// mutable buffer is per-replica and everything shared is
    /// immutable, so N replicas forward concurrently on N threads with
    /// outputs bit-identical to the original's.
    pub fn replicate(&self) -> NetPlan {
        let slots: Vec<Vec<f32>> =
            self.slots.iter().map(|s| Vec::with_capacity(s.capacity())).collect();
        let mut workspace = Workspace::new();
        workspace
            .ensure_bytes(self.max_ws_bytes)
            .expect("compile already reserved this workspace size under the cap");
        NetPlan {
            graph: self.graph.clone(),
            shapes: self.shapes.clone(),
            layouts: self.layouts.clone(),
            batch: self.batch,
            backend_name: self.backend_name,
            steps: self.steps.clone(),
            slot_of: self.slot_of.clone(),
            slots,
            planned_arena_elems: self.planned_arena_elems,
            max_ws_bytes: self.max_ws_bytes,
            workspace,
            node_seconds: vec![0.0; self.node_seconds.len()],
        }
    }

    /// Run one forward pass, writing the class probabilities into a
    /// caller-owned slice (`batch × classes`, fully overwritten). The
    /// hot path: activations live in the plan's arena, conv scratch in
    /// the pre-grown shared workspace, so the steady state allocates no
    /// buffers. `backend` must be the backend the plan was compiled
    /// for.
    pub fn forward_into(
        &mut self,
        backend: &dyn Backend,
        input: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        if backend.name() != self.backend_name {
            bail!(
                "plan was compiled for backend '{}', got '{}'",
                self.backend_name,
                backend.name()
            );
        }
        if input.len() != self.input_elems() {
            bail!("input has {} f32s, expected {}", input.len(), self.input_elems());
        }
        if out.len() != self.output_elems() {
            bail!("output has {} f32s, expected {}", out.len(), self.output_elems());
        }
        let n = self.batch;
        for id in 0..self.graph.len() {
            let started = Instant::now();
            let so = self.slot_of[id];
            let need = n * carrier_elems(self.shapes[id], self.layouts[id]);
            // Take the output slot out of the arena; `resize` stays
            // within the compile-time capacity (no reallocation).
            let mut buf = std::mem::take(&mut self.slots[so]);
            debug_assert!(buf.capacity() >= need, "arena slot under-planned");
            buf.resize(need, 0.0);
            let node = self.graph.node(id);
            match (&node.op, &self.steps[id]) {
                (Op::Input(_), _) => buf.copy_from_slice(input),
                (Op::Conv { relu, .. }, StepRes::Conv { plan, filters, bias }) => {
                    let src = node.inputs[0];
                    let xs = self.shapes[src];
                    let os = self.shapes[id];
                    // Carrier channel counts: blocked values travel in
                    // channel-padded tensors (the lowering pass keeps a
                    // conv's input and output layouts equal).
                    let xc = match self.layouts[src] {
                        TensorLayout::Nchw => xs.c,
                        TensorLayout::Nchwc => blocked_channels(xs.c),
                    };
                    let blocked = self.layouts[id] == TensorLayout::Nchwc;
                    let yc = if blocked { blocked_channels(os.c) } else { os.c };
                    // Move the input slot's buffer into a Tensor for
                    // the backend call (and back) — both moves are
                    // O(1), no copy. Input and output slots are
                    // distinct by liveness construction.
                    let si = self.slot_of[src];
                    let x = Tensor::from_vec(
                        n,
                        xc,
                        xs.h,
                        xs.w,
                        std::mem::take(&mut self.slots[si]),
                    );
                    let mut y = Tensor::from_vec(n, yc, os.h, os.w, buf);
                    let result = backend
                        .execute_into(plan, &x, filters, &mut self.workspace, &mut y);
                    self.slots[si] = x.into_vec();
                    buf = y.into_vec();
                    // Restore the output slot before propagating, so a
                    // transient backend error cannot strand an empty
                    // slot in the arena (later forwards would silently
                    // reallocate it).
                    if let Err(e) = result {
                        self.slots[so] = buf;
                        return Err(e.context(format!("conv node '{}' failed", node.name)));
                    }
                    let os_plane = os.h * os.w;
                    if blocked {
                        ops::bias_relu_nchwc_inplace(&mut buf, os.c, os_plane, bias, *relu);
                    } else {
                        ops::bias_relu_inplace(&mut buf, os.c, os_plane, bias, *relu);
                    }
                }
                (Op::LayoutConvert { .. }, _) => {
                    let src = node.inputs[0];
                    let xs = self.shapes[src];
                    let sbuf = &self.slots[self.slot_of[src]];
                    match (self.layouts[src], self.layouts[id]) {
                        (TensorLayout::Nchw, TensorLayout::Nchwc) => {
                            nchw_to_nchwc(n, xs.c, xs.h, xs.w, sbuf, &mut buf);
                        }
                        (TensorLayout::Nchwc, TensorLayout::Nchw) => {
                            nchwc_to_nchw(n, xs.c, xs.h, xs.w, sbuf, &mut buf);
                        }
                        // Copy-through (the lowering tail's output pin).
                        _ => buf.copy_from_slice(sbuf),
                    }
                }
                (Op::MaxPool(p), _) => {
                    let src = node.inputs[0];
                    ops::max_pool_into(
                        &self.slots[self.slot_of[src]],
                        n,
                        self.shapes[src],
                        *p,
                        &mut buf,
                    );
                }
                (Op::AvgPool(p), _) => {
                    let src = node.inputs[0];
                    ops::avg_pool_into(
                        &self.slots[self.slot_of[src]],
                        n,
                        self.shapes[src],
                        *p,
                        &mut buf,
                    );
                }
                (Op::Concat, _) => {
                    let os = self.shapes[id];
                    let plane = os.h * os.w;
                    let mut c_off = 0usize;
                    for &src in &node.inputs {
                        let cs = self.shapes[src].c;
                        ops::concat_part_into(
                            &self.slots[self.slot_of[src]],
                            n,
                            plane,
                            (cs, c_off, os.c),
                            &mut buf,
                        );
                        c_off += cs;
                    }
                }
                (Op::ResidualAdd { relu }, _) => {
                    let a = &self.slots[self.slot_of[node.inputs[0]]];
                    let b = &self.slots[self.slot_of[node.inputs[1]]];
                    ops::residual_add_into(a, b, *relu, &mut buf);
                }
                (Op::Linear { relu, .. }, StepRes::Linear(lw)) => {
                    let src = node.inputs[0];
                    ops::linear_into(
                        &self.slots[self.slot_of[src]],
                        n,
                        lw,
                        *relu,
                        &mut buf,
                    );
                }
                (Op::Softmax, _) => {
                    let src = node.inputs[0];
                    let classes = self.shapes[src].elems();
                    ops::softmax_into(
                        &self.slots[self.slot_of[src]],
                        n,
                        classes,
                        &mut buf,
                    );
                }
                (op, _) => bail!("node '{}': no resources for {}", node.name, op.kind()),
            }
            self.slots[so] = buf;
            self.node_seconds[id] = started.elapsed().as_secs_f64();
        }
        out.copy_from_slice(&self.slots[self.slot_of[self.graph.output_id()]]);
        Ok(())
    }

    /// Allocating convenience wrapper around [`NetPlan::forward_into`].
    pub fn forward(&mut self, backend: &dyn Backend, input: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.output_elems()];
        self.forward_into(backend, input, &mut out)?;
        Ok(out)
    }

    /// Reference execution with a fresh buffer per node and **no**
    /// arena reuse — the oracle the arena-backed [`forward_into`] is
    /// verified against (a liveness or slot-aliasing bug would diverge
    /// here). Same plans, same weights, different memory discipline.
    /// Verification harnesses only; allocates per node.
    ///
    /// [`forward_into`]: NetPlan::forward_into
    pub fn forward_reference(
        &mut self,
        backend: &dyn Backend,
        input: &[f32],
    ) -> Result<Vec<f32>> {
        if input.len() != self.input_elems() {
            bail!("input has {} f32s, expected {}", input.len(), self.input_elems());
        }
        let n = self.batch;
        let mut values: Vec<Vec<f32>> = Vec::with_capacity(self.graph.len());
        for id in 0..self.graph.len() {
            let node = self.graph.node(id);
            let os = self.shapes[id];
            let mut buf = vec![0.0f32; n * carrier_elems(os, self.layouts[id])];
            match (&node.op, &self.steps[id]) {
                (Op::Input(_), _) => buf.copy_from_slice(input),
                (Op::Conv { relu, .. }, StepRes::Conv { plan, filters, bias }) => {
                    let src = node.inputs[0];
                    let xs = self.shapes[src];
                    let xc = match self.layouts[src] {
                        TensorLayout::Nchw => xs.c,
                        TensorLayout::Nchwc => blocked_channels(xs.c),
                    };
                    let blocked = self.layouts[id] == TensorLayout::Nchwc;
                    let yc = if blocked { blocked_channels(os.c) } else { os.c };
                    let x = Tensor::from_vec(n, xc, xs.h, xs.w, values[src].clone());
                    let mut y = Tensor::from_vec(n, yc, os.h, os.w, buf);
                    backend.execute_into(plan, &x, filters, &mut self.workspace, &mut y)?;
                    buf = y.into_vec();
                    if blocked {
                        ops::bias_relu_nchwc_inplace(&mut buf, os.c, os.h * os.w, bias, *relu);
                    } else {
                        ops::bias_relu_inplace(&mut buf, os.c, os.h * os.w, bias, *relu);
                    }
                }
                (Op::LayoutConvert { .. }, _) => {
                    let src = node.inputs[0];
                    let xs = self.shapes[src];
                    match (self.layouts[src], self.layouts[id]) {
                        (TensorLayout::Nchw, TensorLayout::Nchwc) => {
                            nchw_to_nchwc(n, xs.c, xs.h, xs.w, &values[src], &mut buf);
                        }
                        (TensorLayout::Nchwc, TensorLayout::Nchw) => {
                            nchwc_to_nchw(n, xs.c, xs.h, xs.w, &values[src], &mut buf);
                        }
                        _ => buf.copy_from_slice(&values[src]),
                    }
                }
                (Op::MaxPool(p), _) => {
                    let src = node.inputs[0];
                    ops::max_pool_into(&values[src], n, self.shapes[src], *p, &mut buf);
                }
                (Op::AvgPool(p), _) => {
                    let src = node.inputs[0];
                    ops::avg_pool_into(&values[src], n, self.shapes[src], *p, &mut buf);
                }
                (Op::Concat, _) => {
                    let plane = os.h * os.w;
                    let mut c_off = 0usize;
                    for &src in &node.inputs {
                        let cs = self.shapes[src].c;
                        ops::concat_part_into(
                            &values[src],
                            n,
                            plane,
                            (cs, c_off, os.c),
                            &mut buf,
                        );
                        c_off += cs;
                    }
                }
                (Op::ResidualAdd { relu }, _) => {
                    ops::residual_add_into(
                        &values[node.inputs[0]],
                        &values[node.inputs[1]],
                        *relu,
                        &mut buf,
                    );
                }
                (Op::Linear { relu, .. }, StepRes::Linear(lw)) => {
                    ops::linear_into(&values[node.inputs[0]], n, lw, *relu, &mut buf);
                }
                (Op::Softmax, _) => {
                    let src = node.inputs[0];
                    let classes = self.shapes[src].elems();
                    ops::softmax_into(&values[src], n, classes, &mut buf);
                }
                (op, _) => bail!("node '{}': no resources for {}", node.name, op.kind()),
            }
            values.push(buf);
        }
        values
            .pop()
            .ok_or_else(|| anyhow!("graph '{}' has no nodes", self.graph.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::CpuRefBackend;
    use crate::cpuref::naive::conv_naive;
    use crate::net::graph::GraphBuilder;

    fn planner() -> NetPlanner {
        NetPlanner::new(Box::new(CpuRefBackend::new()))
    }

    fn rand_input(plan: &NetPlan, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; plan.input_elems()];
        rng.fill_uniform(&mut v, -1.0, 1.0);
        v
    }

    /// A small graph exercising every operator: two conv branches,
    /// concat, residual join, both pools and the linear+softmax tail.
    fn every_op_graph() -> NetGraph {
        let mut b = GraphBuilder::new("every-op", 3, 12, 12);
        let stem = b.conv("stem", b.input(), 8, 3, 1, 1);
        let p = b.max_pool("pool", stem, 2, 2, 0); // 6x6
        let br1 = b.conv_same("br1", p, 4, 1);
        let br2 = b.conv_same("br2", p, 4, 3);
        let cat = b.concat("cat", vec![br1, br2]); // 8ch
        let mix = b.conv_linear("mix", cat, 8, 1, 1, 0);
        let res = b.residual_add("res", mix, p, true);
        let gap = b.global_avg_pool("gap", res);
        let fc = b.linear("fc", gap, 10, false);
        b.softmax("softmax", fc);
        b.finish()
    }

    #[test]
    fn conv_node_matches_naive_oracle_plus_epilogue() {
        // Single conv (bias + ReLU epilogue) against conv_naive with a
        // hand-applied epilogue, via the exposed seeded parameters.
        let mut b = GraphBuilder::new("one-conv", 3, 9, 9);
        let _c = b.conv("c", b.input(), 5, 3, 2, 1); // stride-2, padded
        let graph = b.finish();
        let p = planner();
        let mut plan = p.compile(&graph, 2).unwrap();
        let input = rand_input(&plan, 7);
        let got = plan.forward(p.backend(), &input).unwrap();

        let (filters, bias) = plan.conv_params(plan.node_id("c").unwrap()).unwrap();
        let spec = ConvSpec {
            n: 2, c: 3, h: 9, w: 9, m: 5, kh: 3, kw: 3, stride: 2, pad_h: 1, pad_w: 1,
        };
        let x = Tensor::from_vec(2, 3, 9, 9, input);
        let oracle = conv_naive(&spec, &x, filters);
        let (oh, ow) = (spec.out_h(), spec.out_w());
        let mut want = oracle.into_vec();
        for (ch, row) in want.chunks_exact_mut(oh * ow).enumerate() {
            for v in row.iter_mut() {
                *v = (*v + bias[ch % 5]).max(0.0);
            }
        }
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn arena_ping_pongs_a_chain_into_few_slots() {
        let mut b = GraphBuilder::new("chain", 2, 10, 10);
        let mut x = b.input();
        for i in 0..6 {
            x = b.conv_same(&format!("c{i}"), x, 2, 3);
        }
        let plan = planner().compile(&b.finish(), 1).unwrap();
        // A pure chain needs exactly two live values at any node.
        assert_eq!(plan.slot_count(), 2, "chain should ping-pong two slots");
        assert!(plan.planned_arena_bytes() <= 2 * 2 * 10 * 10 * F32_BYTES);
    }

    #[test]
    fn arena_forward_matches_fresh_buffer_reference() {
        let p = planner();
        let mut plan = p.compile(&every_op_graph(), 2).unwrap();
        let input = rand_input(&plan, 11);
        let want = plan.forward_reference(p.backend(), &input).unwrap();
        // Run the arena path twice (dirty slots on the second pass).
        let _ = plan.forward(p.backend(), &input).unwrap();
        let got = plan.forward(p.backend(), &input).unwrap();
        assert_eq!(got, want, "arena reuse changed the numerics");
    }

    #[test]
    fn forward_is_deterministic_across_dirty_buffers() {
        let p = planner();
        let mut plan = p.compile(&every_op_graph(), 1).unwrap();
        let a = rand_input(&plan, 1);
        let mut rng = Rng::new(2);
        let mut other = vec![0.0f32; plan.input_elems()];
        rng.fill_uniform(&mut other, -1.0, 1.0);
        let first = plan.forward(p.backend(), &a).unwrap();
        let _ = plan.forward(p.backend(), &other).unwrap(); // dirty everything
        let again = plan.forward(p.backend(), &a).unwrap();
        assert_eq!(first, again);
    }

    #[test]
    fn batched_forward_matches_independent_items() {
        // One planner, two plans (batch 1 and 3): same seeded weights,
        // so a batch-3 forward must match three batch-1 forwards.
        // `compile` picks algorithms per batch size, which may differ
        // (heuristics are batch-dependent), hence a float tolerance.
        let p = planner();
        let graph = every_op_graph();
        let mut plan1 = p.compile(&graph, 1).unwrap();
        let mut plan3 = p.compile(&graph, 3).unwrap();
        let item = plan1.input_elems();
        let input = {
            let mut rng = Rng::new(33);
            let mut v = vec![0.0f32; 3 * item];
            rng.fill_uniform(&mut v, -1.0, 1.0);
            v
        };
        let batched = plan3.forward(p.backend(), &input).unwrap();
        let classes = plan1.output_elems();
        for i in 0..3 {
            let single =
                plan1.forward(p.backend(), &input[i * item..(i + 1) * item]).unwrap();
            for (s, b) in single.iter().zip(batched[i * classes..].iter()) {
                assert!((s - b).abs() < 5e-4, "item {i}: {s} vs {b}");
            }
        }
    }

    #[test]
    fn compile_for_sizes_pins_one_algorithm_and_is_grouping_invariant() {
        // The serving form: one algorithm per conv node across all
        // batch sizes, so outputs are *identical* no matter how the
        // batcher groups requests (every kernel processes items
        // independently).
        let p = planner();
        let graph = every_op_graph();
        let plans = p.compile_for_sizes(&graph, &[2, 1]).unwrap();
        assert_eq!(
            plans.iter().map(|(b, _)| *b).collect::<Vec<_>>(),
            vec![1, 2],
            "sorted + deduplicated"
        );
        let mut it = plans.into_iter();
        let (_, mut plan1) = it.next().unwrap();
        let (_, mut plan2) = it.next().unwrap();
        assert_eq!(plan1.conv_algorithms(), plan2.conv_algorithms());
        // The per-size plans share one weight set (Arc), not copies —
        // same allocation, not merely equal values.
        let stem = plan1.node_id("stem").unwrap();
        let (f1, _) = plan1.conv_params(stem).unwrap();
        let (f2, _) = plan2.conv_params(stem).unwrap();
        assert!(std::ptr::eq(f1, f2), "weights duplicated across batch sizes");
        let item = plan1.input_elems();
        let mut rng = Rng::new(44);
        let mut input = vec![0.0f32; 2 * item];
        rng.fill_uniform(&mut input, -1.0, 1.0);
        let batched = plan2.forward(p.backend(), &input).unwrap();
        let classes = plan1.output_elems();
        for i in 0..2 {
            let single =
                plan1.forward(p.backend(), &input[i * item..(i + 1) * item]).unwrap();
            assert_eq!(
                single,
                batched[i * classes..(i + 1) * classes].to_vec(),
                "item {i} depends on batch grouping"
            );
        }
    }

    /// Plan-time packed weights (the tiled cuConv panels) must exist
    /// once per weight set per fleet: shared across the per-batch-size
    /// plans of `compile_for_sizes` AND across `replicate()` shards —
    /// the same `Arc`, not equal copies.
    #[test]
    fn packed_weights_are_shared_across_sizes_and_replicas() {
        // Nchw policy keeps the conv on the *tiled* packed path (the
        // register-tile panels this test pins); blocked-panel sharing
        // is asserted by `blocked_panels_are_shared_like_tiled_ones`.
        let p = planner().with_layout(LayoutPolicy::Nchw);
        // A batch-1 small 1×1 conv pins cuConv across sizes (heuristic
        // region), which is the algorithm that owns packed weights.
        let mut gb = GraphBuilder::new("pack", 16, 7, 7);
        let c = gb.conv_same("c", gb.input(), 32, 1);
        let g = gb.global_avg_pool("gap", c);
        let fc = gb.linear("fc", g, 4, false);
        gb.softmax("sm", fc);
        let graph = gb.finish();
        let plans = p.compile_for_sizes(&graph, &[1, 2]).unwrap();
        let (_, plan1) = &plans[0];
        let (_, plan2) = &plans[1];
        assert_eq!(
            plan1.conv_plan(c).unwrap().algo(),
            Algorithm::CuConv,
            "test premise: this conv must pin cuConv"
        );
        let pk1 = plan1
            .conv_plan(c)
            .unwrap()
            .packed_filters()
            .expect("cuconv plan must own packed weights");
        let pk2 = plan2.conv_plan(c).unwrap().packed_filters().unwrap();
        assert!(Arc::ptr_eq(pk1, pk2), "packing duplicated across batch sizes");
        // Replication (sharded serving) shares the same packing.
        let replica = plan1.replicate();
        let pkr = replica.conv_plan(c).unwrap().packed_filters().unwrap();
        assert!(Arc::ptr_eq(pk1, pkr), "replicate must share the packing");
        // And the packed tile is one of the closed candidate set.
        assert!(crate::cpuref::pack::TileShape::CANDIDATES.contains(&pk1.tile()));
    }

    #[test]
    fn replicate_shares_weights_but_owns_arena_and_workspace() {
        let p = planner();
        let mut plan = p.compile(&every_op_graph(), 2).unwrap();
        let input = rand_input(&plan, 51);
        let want = plan.forward(p.backend(), &input).unwrap();
        let mut replica = plan.replicate();
        // Shared: the weight allocations themselves and the algorithm
        // choices (not merely equal values).
        let stem = plan.node_id("stem").unwrap();
        let (f0, _) = plan.conv_params(stem).unwrap();
        let (f1, _) = replica.conv_params(stem).unwrap();
        assert!(std::ptr::eq(f0, f1), "replicate must share weights via Arc");
        assert_eq!(plan.conv_algorithms(), replica.conv_algorithms());
        // Per-replica: a fresh arena and workspace at the planned sizes.
        assert_eq!(replica.planned_arena_bytes(), plan.planned_arena_bytes());
        assert_eq!(replica.max_conv_workspace_bytes(), plan.max_conv_workspace_bytes());
        assert!(replica.workspace().capacity_bytes() >= replica.max_conv_workspace_bytes());
        // Bit-identical outputs, including after interleaved forwards
        // that dirty both replicas' private buffers.
        let got = replica.forward(p.backend(), &input).unwrap();
        assert_eq!(got, want, "replica numerics diverged");
        let other = rand_input(&plan, 52);
        let _ = plan.forward(p.backend(), &other).unwrap();
        let again = replica.forward(p.backend(), &input).unwrap();
        assert_eq!(again, want);
    }

    #[test]
    fn replicas_forward_concurrently_and_agree() {
        let p = planner();
        let plan = p.compile(&every_op_graph(), 1).unwrap();
        let input = {
            let mut rng = Rng::new(77);
            let mut v = vec![0.0f32; plan.input_elems()];
            rng.fill_uniform(&mut v, -1.0, 1.0);
            v
        };
        let outs: Vec<Vec<f32>> = std::thread::scope(|s| {
            let joins: Vec<_> = (0..3)
                .map(|_| {
                    let mut replica = plan.replicate();
                    let backend = p.backend();
                    let input = input.clone();
                    s.spawn(move || replica.forward(backend, &input).unwrap())
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert!(outs.windows(2).all(|w| w[0] == w[1]), "replicas disagree");
    }

    #[test]
    fn steady_state_is_allocation_flat() {
        let p = planner();
        let mut plan = p.compile(&every_op_graph(), 2).unwrap();
        let input = rand_input(&plan, 5);
        let _ = plan.forward(p.backend(), &input).unwrap();
        let arena = plan.arena_capacity_bytes();
        let ws_cap = plan.workspace().capacity_bytes();
        let ws_high = plan.workspace().high_water_bytes();
        assert!(arena > 0);
        for _ in 0..20 {
            let _ = plan.forward(p.backend(), &input).unwrap();
            assert_eq!(plan.arena_capacity_bytes(), arena, "arena grew");
            assert_eq!(plan.workspace().capacity_bytes(), ws_cap, "workspace grew");
            assert_eq!(plan.workspace().high_water_bytes(), ws_high);
        }
    }

    #[test]
    fn workspace_is_sized_to_the_max_conv_requirement() {
        let p = planner();
        let plan = p.compile(&every_op_graph(), 2).unwrap();
        let max_plan_ws = plan
            .graph()
            .nodes()
            .iter()
            .enumerate()
            .filter_map(|(id, _)| plan.conv_params(id).map(|_| id))
            .map(|id| plan.layer_report()[id].workspace_bytes)
            .max()
            .unwrap();
        assert_eq!(plan.max_conv_workspace_bytes(), max_plan_ws);
        assert!(plan.workspace().capacity_bytes() >= max_plan_ws);
    }

    #[test]
    fn measured_choice_compiles_and_runs() {
        let p = planner().with_choice(AlgoChoice::Measured { iters: 1 });
        let mut b = GraphBuilder::new("tiny", 2, 8, 8);
        let c = b.conv_same("c", b.input(), 3, 3);
        let g = b.global_avg_pool("gap", c);
        let fc = b.linear("fc", g, 4, false);
        b.softmax("sm", fc);
        let mut plan = p.compile(&b.finish(), 1).unwrap();
        let input = rand_input(&plan, 9);
        let probs = plan.forward(p.backend(), &input).unwrap();
        assert_eq!(probs.len(), 4);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert_eq!(plan.conv_algorithms().len(), 1);
    }

    /// The warm-start property: a measured compile recorded into a
    /// [`TuneCache`], round-tripped through save → load (real bytes,
    /// bit-identical), must replay the **exact** cold-plan `Algorithm`
    /// and `TileShape` choices with zero timing measurements.
    #[test]
    fn tune_cache_warm_plan_replays_cold_choices_with_zero_measurements() {
        let mut gb = GraphBuilder::new("tune", 3, 8, 8);
        let c1 = gb.conv_same("c1", gb.input(), 8, 3);
        let c2 = gb.conv_same("c2", c1, 4, 1);
        let g = gb.global_avg_pool("gap", c2);
        let fc = gb.linear("fc", g, 4, false);
        gb.softmax("sm", fc);
        let graph = gb.finish();

        let compile = |cache: Arc<TuneCache>| {
            let backend =
                CpuRefBackend::new().with_measured_tiles(1).with_tune_cache(cache.clone());
            let planner = NetPlanner::new(Box::new(backend))
                .with_choice(AlgoChoice::Measured { iters: 1 })
                .with_tune_cache(cache);
            planner.compile_for_sizes(&graph, &[1, 2]).unwrap()
        };
        let tiles_of = |plans: &[(usize, NetPlan)]| -> Vec<Option<_>> {
            plans[0]
                .1
                .graph()
                .nodes()
                .iter()
                .enumerate()
                .map(|(id, _)| {
                    plans[0].1.conv_plan(id).and_then(|p| {
                        p.packed_filters().map(|pk| pk.tile())
                    })
                })
                .collect()
        };

        // Cold: measure everything, record into the cache.
        let cold_cache = Arc::new(TuneCache::new());
        let before_cold = crate::tunecache::measurement_count();
        let cold_plans = compile(cold_cache.clone());
        assert!(
            crate::tunecache::measurement_count() > before_cold,
            "cold compile must measure"
        );
        let cold_algos = cold_plans[0].1.conv_algorithms();
        let cold_tiles = tiles_of(&cold_plans);

        // Round-trip through real file bytes.
        let path = std::env::temp_dir()
            .join(format!("cuconv_planner_tunecache_{}.json", std::process::id()));
        cold_cache.save(&path).unwrap();
        let warm_cache = Arc::new(TuneCache::load(&path));
        assert_eq!(warm_cache.degraded(), 0);
        assert_eq!(
            warm_cache.to_json().to_string_pretty(),
            cold_cache.to_json().to_string_pretty(),
            "save -> load must be bit-identical"
        );
        std::fs::remove_file(&path).ok();

        // Warm: zero measurements, identical choices.
        let before_warm = crate::tunecache::measurement_count();
        let warm_plans = compile(warm_cache.clone());
        assert_eq!(
            crate::tunecache::measurement_count(),
            before_warm,
            "warm compile with a populated cache must measure nothing"
        );
        assert!(warm_cache.hits() > 0);
        assert_eq!(warm_plans[0].1.conv_algorithms(), cold_algos);
        assert_eq!(tiles_of(&warm_plans), cold_tiles);
    }

    #[test]
    fn forward_rejects_bad_arguments() {
        let p = planner();
        let mut plan = p.compile(&every_op_graph(), 1).unwrap();
        let input = rand_input(&plan, 3);
        // Wrong input length.
        assert!(plan.forward(p.backend(), &input[1..]).is_err());
        // Wrong output length.
        let mut short = vec![0.0f32; plan.output_elems() - 1];
        assert!(plan.forward_into(p.backend(), &input, &mut short).is_err());
        // Wrong backend.
        struct OtherName;
        impl Backend for OtherName {
            fn name(&self) -> &'static str {
                "other"
            }
            fn capabilities(
                &self,
                _: &ConvSpec,
                _: Algorithm,
            ) -> crate::backend::Support {
                crate::backend::Support::Unsupported("stub")
            }
            fn plan(&self, _: &ConvDescriptor, _: Algorithm) -> Result<ConvPlan> {
                bail!("stub")
            }
            fn execute_into(
                &self,
                _: &ConvPlan,
                _: &Tensor,
                _: &Tensor,
                _: &mut Workspace,
                _: &mut Tensor,
            ) -> Result<()> {
                bail!("stub")
            }
        }
        assert!(plan.forward(&OtherName, &input).is_err());
        // Zero batch refused at compile time.
        assert!(p.compile(&every_op_graph(), 0).is_err());
    }

    #[test]
    fn layer_report_covers_every_node_with_times() {
        let p = planner();
        let mut plan = p.compile(&every_op_graph(), 1).unwrap();
        let input = rand_input(&plan, 21);
        let _ = plan.forward(p.backend(), &input).unwrap();
        let report = plan.layer_report();
        assert_eq!(report.len(), plan.graph().len());
        assert!(report.iter().all(|l| l.seconds >= 0.0));
        assert!(report.iter().any(|l| l.kind == "conv" && l.algo.is_some()));
        assert!(report.iter().filter(|l| l.kind == "conv").count() == 4);
        assert!(plan.total_seconds() > 0.0);
        assert!(plan.conv_seconds() <= plan.total_seconds());
    }

    #[test]
    fn layout_pass_elides_interior_converts_on_a_conv_chain() {
        let mut b = GraphBuilder::new("chain", 3, 10, 10);
        let c1 = b.conv_same("c1", b.input(), 8, 3);
        let _ = b.conv_same("c2", c1, 8, 3);
        let graph = b.finish();
        let p = planner().with_layout(LayoutPolicy::Nchwc);
        let mut plan = p.compile(&graph, 1).unwrap();
        // Exactly one ingress + one egress convert, zero interior:
        // input -> to-blocked -> c1 -> c2 -> to-plain.
        assert_eq!(
            plan.convert_count(),
            2,
            "graph: {:?}",
            plan.graph().nodes().iter().map(|n| n.name.as_str()).collect::<Vec<_>>()
        );
        let g = plan.graph();
        for (id, node) in g.nodes().iter().enumerate() {
            match &node.op {
                Op::LayoutConvert { .. } => assert!(
                    !matches!(g.node(node.inputs[0]).op, Op::LayoutConvert { .. }),
                    "back-to-back converts survived elision"
                ),
                Op::Conv { .. } => {
                    assert_eq!(plan.node_layouts()[id], TensorLayout::Nchwc);
                    assert_eq!(plan.node_layouts()[node.inputs[0]], TensorLayout::Nchwc);
                }
                _ => {}
            }
        }
        let (c1, c2) = (plan.node_id("c1").unwrap(), plan.node_id("c2").unwrap());
        assert_eq!(g.node(c2).inputs, vec![c1], "conv->conv edge must be direct");
        // And it runs, bit-identical to the fresh-buffer reference.
        let input = rand_input(&plan, 0xE11D);
        let want = plan.forward_reference(p.backend(), &input).unwrap();
        let got = plan.forward(p.backend(), &input).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn blocked_plan_is_bit_identical_to_the_plain_cuconv_plan() {
        // Every conv sits in the cuConv heuristic region (batch 1, tiny
        // spatial dims), so the Nchw-policy plan runs tiled cuConv and
        // the Nchwc-policy plan runs the blocked microkernel — both are
        // bit-identical to conv_naive, hence to each other, end to end.
        // Channel counts are deliberately not block multiples (5, 12,
        // 10) so padded-lane tails flow through a whole network.
        let mut gb = GraphBuilder::new("bitnet", 5, 7, 7);
        let c1 = gb.conv_same("c1", gb.input(), 12, 3);
        let c2 = gb.conv_same("c2", c1, 10, 1);
        let g2 = gb.global_avg_pool("gap", c2);
        let fc = gb.linear("fc", g2, 6, false);
        gb.softmax("sm", fc);
        let graph = gb.finish();

        let plain_p = planner().with_layout(LayoutPolicy::Nchw);
        let blocked_p = planner().with_layout(LayoutPolicy::Nchwc);
        let mut plain = plain_p.compile(&graph, 1).unwrap();
        let mut blocked = blocked_p.compile(&graph, 1).unwrap();
        for plan in [&plain, &blocked] {
            assert!(
                plan.conv_algorithms().iter().all(|(_, a)| *a == Algorithm::CuConv),
                "test premise: every conv must run cuConv, got {:?}",
                plan.conv_algorithms()
            );
        }
        assert_eq!(plain.convert_count(), 0);
        assert_eq!(blocked.convert_count(), 2, "one ingress + one egress");
        assert_eq!(
            blocked.node_layouts()[blocked.node_id("c1").unwrap()],
            TensorLayout::Nchwc
        );

        let input = rand_input(&plain, 0xB10C);
        let want = plain.forward(plain_p.backend(), &input).unwrap();
        let got = blocked.forward(blocked_p.backend(), &input).unwrap();
        assert_eq!(got, want, "blocked whole-net forward is not bit-identical");
        let reference = blocked.forward_reference(blocked_p.backend(), &input).unwrap();
        assert_eq!(reference, want);
    }

    #[test]
    fn blocked_execution_is_allocation_flat_with_zero_conv_workspace() {
        let p = planner().with_layout(LayoutPolicy::Nchwc);
        let mut plan = p.compile(&every_op_graph(), 2).unwrap();
        assert!(plan.convert_count() > 0, "premise: the lowering blockified convs");
        // Every conv runs the workspace-free blocked microkernel.
        assert_eq!(plan.max_conv_workspace_bytes(), 0);
        let input = rand_input(&plan, 0xF1A7);
        let want = plan.forward_reference(p.backend(), &input).unwrap();
        let _ = plan.forward(p.backend(), &input).unwrap();
        let arena = plan.arena_capacity_bytes();
        let ws_cap = plan.workspace().capacity_bytes();
        for _ in 0..20 {
            let got = plan.forward(p.backend(), &input).unwrap();
            assert_eq!(got, want, "dirty-arena blocked forward diverged");
            assert_eq!(plan.arena_capacity_bytes(), arena, "arena grew");
            assert_eq!(plan.workspace().capacity_bytes(), ws_cap, "workspace grew");
            assert_eq!(
                plan.workspace().high_water_bytes(),
                0,
                "a blocked conv touched the workspace"
            );
        }
    }

    #[test]
    fn blocked_panels_are_shared_like_tiled_ones() {
        let mut gb = GraphBuilder::new("bpack", 16, 7, 7);
        let c = gb.conv_same("c", gb.input(), 32, 1);
        let g = gb.global_avg_pool("gap", c);
        let fc = gb.linear("fc", g, 4, false);
        gb.softmax("sm", fc);
        let graph = gb.finish();
        let p = planner().with_layout(LayoutPolicy::Nchwc);
        let plans = p.compile_for_sizes(&graph, &[1, 2]).unwrap();
        let (_, plan1) = &plans[0];
        let (_, plan2) = &plans[1];
        let c = plan1.node_id("c").unwrap();
        let cp = plan1.conv_plan(c).unwrap();
        assert_eq!(cp.algo(), Algorithm::CuConv);
        assert_eq!(cp.layout(), TensorLayout::Nchwc);
        let pk1 = cp.packed_filters().expect("blocked plan must own packed panels");
        assert_eq!(pk1.tile(), crate::cpuref::pack::nchwc_tile());
        let pk2 = plan2.conv_plan(c).unwrap().packed_filters().unwrap();
        assert!(Arc::ptr_eq(pk1, pk2), "blocked packing duplicated across sizes");
        let replica = plan1.replicate();
        let pkr = replica.conv_plan(c).unwrap().packed_filters().unwrap();
        assert!(Arc::ptr_eq(pk1, pkr), "replicate must share the blocked packing");
    }

    #[test]
    fn nchw_policy_compiles_the_pre_layout_plan() {
        let graph = every_op_graph();
        let p = planner().with_layout(LayoutPolicy::Nchw);
        let plan = p.compile(&graph, 1).unwrap();
        assert_eq!(plan.graph().len(), graph.len(), "Nchw policy must not rewrite");
        assert_eq!(plan.convert_count(), 0);
        assert!(plan.node_layouts().iter().all(|&l| l == TensorLayout::Nchw));
    }

    #[test]
    fn authored_convert_round_trips_collapse_to_the_source() {
        // A hand-built graph ending in a redundant blocked round-trip:
        // the pass elides the pair, pins the output as the last node
        // via a copy-through, and the forward is the identity.
        let shape = FeatShape::new(3, 4, 4);
        let graph = NetGraph::from_parts(
            "roundtrip",
            vec![
                Node { name: "in".into(), op: Op::Input(shape), inputs: vec![] },
                Node {
                    name: "blk".into(),
                    op: Op::LayoutConvert { to: TensorLayout::Nchwc },
                    inputs: vec![0],
                },
                Node {
                    name: "back".into(),
                    op: Op::LayoutConvert { to: TensorLayout::Nchw },
                    inputs: vec![1],
                },
            ],
        );
        let p = planner();
        let mut plan = p.compile(&graph, 1).unwrap();
        let input = rand_input(&plan, 0x1D);
        let got = plan.forward(p.backend(), &input).unwrap();
        assert_eq!(got, input, "a convert round-trip must be the identity");
        // The blocked round-trip was elided: no surviving convert reads
        // a blocked value.
        for node in plan.graph().nodes() {
            if matches!(node.op, Op::LayoutConvert { .. }) {
                assert_ne!(
                    plan.node_layouts()[node.inputs[0]],
                    TensorLayout::Nchwc,
                    "the blocked round-trip was not elided"
                );
            }
        }
    }
}
