//! Regeneration of the paper's Figures 5–7 and the §4.1 aggregates.
//!
//! Figures 5–7 plot cuConv's speedup over the best cuDNN variant per
//! configuration, split by filter size, across batch sizes. Here the
//! series come from the calibrated V100 model ([`crate::gpumodel`]);
//! the bench binaries print them and dump CSVs under `results/`.

use crate::conv::FilterSize;
use crate::gpumodel;
use crate::report::{fmt_speedup, Table};
use crate::util::stats::geomean;
use crate::zoo;

/// The batch sizes each figure shows (figures 5 and 6 are truncated in
/// the paper "to focus on the relevant results").
pub fn figure_batches(filter: FilterSize) -> &'static [usize] {
    match filter {
        FilterSize::F1x1 => &[1, 8, 16, 32, 64],
        FilterSize::F3x3 => &[1, 8, 16],
        _ => &[1, 8, 16, 32, 64, 128, 256],
    }
}

/// Figure number for a filter size (paper numbering).
pub fn figure_number(filter: FilterSize) -> u8 {
    match filter {
        FilterSize::F1x1 => 5,
        FilterSize::F3x3 => 6,
        _ => 7,
    }
}

/// One figure: speedup per (config, batch).
pub fn figure_speedups(filter: FilterSize) -> Table {
    let batches = figure_batches(filter);
    let mut headers: Vec<&str> = vec!["config"];
    let batch_headers: Vec<String> = batches.iter().map(|b| format!("b={b}")).collect();
    headers.extend(batch_headers.iter().map(|s| s.as_str()));
    let mut table = Table::new(
        format!(
            "Figure {}: cuConv speedup vs best baseline, {} filters (model)",
            figure_number(filter),
            filter
        ),
        &headers,
    );
    let mut entries = zoo::configs_with_filter(filter);
    // Paper orders configs by size; sort by (H, M, C) for a stable axis.
    entries.sort_by_key(|e| (e.spec.h, e.spec.m, e.spec.c));
    for entry in entries {
        let mut row = vec![entry.spec.fig_label()];
        for &b in batches {
            let spec = entry.spec.with_batch(b);
            row.push(match gpumodel::speedup(&spec) {
                Some(s) => fmt_speedup(s),
                None => "n/a".to_string(),
            });
        }
        table.row(row);
    }
    table
}

/// §4.1 aggregate reproduction.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepAggregates {
    pub cases: usize,
    pub wins: usize,
    pub win_fraction: f64,
    pub avg_win_speedup: f64,
    pub max_speedup: f64,
    pub max_label: String,
    pub max_batch: usize,
    pub avg_1x1_batch1: f64,
    pub max_1x1_batch1: f64,
    pub max_1x1_label: String,
    pub avg_5x5_batch1: f64,
    pub max_5x5_batch1: f64,
    pub wins_at_batch1: usize,
}

/// Run the full 616-case sweep and aggregate like §4.1.
pub fn sweep_aggregates() -> SweepAggregates {
    let mut wins = Vec::new();
    let mut cases = 0usize;
    let mut max = (0.0f64, String::new(), 0usize);
    let mut f1b1 = Vec::new();
    let mut f5b1 = Vec::new();
    let mut wins_b1 = 0usize;
    let mut max_1x1 = (0.0f64, String::new());
    for (entry, batch) in zoo::all_cases() {
        let spec = entry.spec.with_batch(batch);
        let Some(s) = gpumodel::speedup(&spec) else { continue };
        cases += 1;
        if s > 1.0 {
            wins.push(s);
            if batch == 1 {
                wins_b1 += 1;
            }
        }
        if s > max.0 {
            max = (s, spec.fig_label(), batch);
        }
        if batch == 1 {
            match spec.filter_size() {
                FilterSize::F1x1 => {
                    if s > max_1x1.0 {
                        max_1x1 = (s, spec.fig_label());
                    }
                    f1b1.push(s);
                }
                FilterSize::F5x5 => f5b1.push(s),
                _ => {}
            }
        }
    }
    SweepAggregates {
        cases,
        wins: wins.len(),
        win_fraction: wins.len() as f64 / cases as f64,
        avg_win_speedup: if wins.is_empty() { 0.0 } else { geomean(&wins) },
        max_speedup: max.0,
        max_label: max.1,
        max_batch: max.2,
        avg_1x1_batch1: geomean(&f1b1),
        max_1x1_batch1: max_1x1.0,
        max_1x1_label: max_1x1.1,
        avg_5x5_batch1: geomean(&f5b1),
        max_5x5_batch1: f5b1.iter().copied().fold(0.0, f64::max),
        wins_at_batch1: wins_b1,
    }
}

/// The §4.1 aggregates as a paper-vs-model table.
pub fn aggregates_table() -> Table {
    use crate::gpumodel::paper::claims;
    let a = sweep_aggregates();
    let mut t = Table::new(
        "§4.1 aggregates: paper vs model",
        &["metric", "paper", "model"],
    );
    t.row(vec![
        "avg speedup, 1x1, batch 1".into(),
        format!("{:.2}x", claims::AVG_SPEEDUP_1X1_B1),
        fmt_speedup(a.avg_1x1_batch1),
    ]);
    t.row(vec![
        "max speedup, 1x1, batch 1".into(),
        format!("{:.2}x (7-32-832)", claims::MAX_SPEEDUP_1X1_B1),
        format!("{} ({})", fmt_speedup(a.max_1x1_batch1), a.max_1x1_label),
    ]);
    t.row(vec![
        "avg speedup, 5x5, batch 1".into(),
        format!("{:.2}x", claims::AVG_SPEEDUP_5X5_B1),
        fmt_speedup(a.avg_5x5_batch1),
    ]);
    t.row(vec![
        "max speedup, 5x5, batch 1".into(),
        format!("{:.2}x", claims::MAX_SPEEDUP_5X5_B1),
        fmt_speedup(a.max_5x5_batch1),
    ]);
    t.row(vec![
        "configs where cuConv wins".into(),
        format!("{:.1}%", 100.0 * claims::WIN_FRACTION),
        format!("{:.1}% ({} of {})", 100.0 * a.win_fraction, a.wins, a.cases),
    ]);
    t.row(vec![
        "avg speedup over wins".into(),
        format!("{:.2}x", claims::AVG_SPEEDUP_WINS),
        fmt_speedup(a.avg_win_speedup),
    ]);
    t.row(vec![
        "wins at batch 1".into(),
        "almost all".into(),
        format!("{} of {}", a.wins_at_batch1, a.wins),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_tables_have_all_configs() {
        let f5 = figure_speedups(FilterSize::F1x1);
        assert!(f5.rows.len() >= 40, "{} 1x1 rows", f5.rows.len());
        assert_eq!(f5.headers.len(), 1 + figure_batches(FilterSize::F1x1).len());
        let f7 = figure_speedups(FilterSize::F5x5);
        assert_eq!(f7.rows.len(), 9);
    }

    #[test]
    fn aggregates_reproduce_claim_shapes() {
        let a = sweep_aggregates();
        assert!(a.cases >= 550);
        assert!(a.max_speedup > 1.5 && a.max_speedup < 4.0);
        assert_eq!(a.max_batch, 1, "max speedup must be at batch 1");
        assert!(a.win_fraction > 0.02 && a.win_fraction < 0.30);
        assert!(a.wins_at_batch1 * 2 > a.wins);
        assert!(a.avg_1x1_batch1 > 0.8);
    }

    #[test]
    fn aggregates_table_renders() {
        let t = aggregates_table();
        assert_eq!(t.rows.len(), 7);
        assert!(t.render().contains("paper"));
    }
}
