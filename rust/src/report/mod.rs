//! Report emitters: aligned text tables, CSV files and JSON dumps for
//! the regenerated paper tables/figures.

pub mod figures;
pub mod tables;

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i];
                if i + 1 == ncols {
                    let _ = write!(out, "{c:<pad$}");
                } else {
                    let _ = write!(out, "{c:<pad$}  ");
                }
            }
            out.push('\n');
        };
        line(&self.headers, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(
            s,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ =
                writeln!(s, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        write_file(path, &s)
    }
}

/// Write a string to a file, creating parent directories.
pub fn write_file(path: impl AsRef<Path>, contents: &str) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    std::fs::write(path, contents).with_context(|| format!("writing {}", path.display()))
}

/// Write a JSON value prettily.
pub fn write_json(path: impl AsRef<Path>, value: &Json) -> Result<()> {
    write_file(path, &value.to_string_pretty())
}

/// Format a speedup for table cells.
pub fn fmt_speedup(s: f64) -> String {
    format!("{s:.2}x")
}

/// Format microseconds.
pub fn fmt_us(us: f64) -> String {
    format!("{us:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["config", "speedup"]);
        t.row(vec!["7-32-832".into(), "2.29x".into()]);
        t.row(vec!["14-1024-256".into(), "0.65x".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("config       speedup"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        Table::new("t", &["a", "b"]).row(vec!["x".into()]);
    }

    #[test]
    fn csv_escapes() {
        let dir = std::env::temp_dir().join("cuconv_report_test");
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("\"x,y\""));
        assert!(s.contains("\"q\"\"z\""));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_speedup(2.288), "2.29x");
        assert_eq!(fmt_us(58.561), "58.56");
    }
}
