//! Regeneration of the paper's Tables 1–5.
//!
//! * Table 1 — the census of conv configurations per network.
//! * Table 2 — the algorithm-variant registry.
//! * Tables 3–5 — per-kernel execution times of the profiled configs:
//!   paper µs (V100) vs model µs, plus — when a measurement
//!   [`Backend`] is supplied — **measured** µs of real executions
//!   through the descriptor → plan → execute API (PJRT artifacts or the
//!   CPU reference backend; ordering among our variants is meaningful,
//!   absolute values are not V100-comparable).

use crate::algo::Algorithm;
use crate::backend::{Backend, ConvDescriptor, Workspace};
use crate::conv::{ConvSpec, FilterSize};
use crate::gpumodel::{self, paper};
use crate::report::{fmt_us, Table};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::zoo;

/// Table 1: summary of the convolution census.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1: stride-1 convolution configurations of the five CNNs",
        &["network", "# distinct", "1x1", "3x3", "5x5", "last conv input"],
    );
    for row in zoo::census() {
        let (h, w, c) = row.network.last_conv_input();
        t.row(vec![
            row.network.name().to_string(),
            row.distinct.to_string(),
            format!("{} ({:.1}%)", row.n_1x1, row.pct(FilterSize::F1x1)),
            format!("{} ({:.1}%)", row.n_3x3, row.pct(FilterSize::F3x3)),
            format!("{} ({:.1}%)", row.n_5x5, row.pct(FilterSize::F5x5)),
            format!("{h}x{w}x{c}"),
        ]);
    }
    t
}

/// Table 2: the algorithm registry.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2: convolution algorithm variants",
        &["algorithm", "kernels (3x3)", "description"],
    );
    let probe = ConvSpec::paper(14, 1, 3, 64, 64);
    for algo in Algorithm::ALL {
        let kernels = if algo.supports(&probe) {
            algo.kernel_count(&probe).to_string()
        } else {
            "-".to_string()
        };
        t.row(vec![algo.name().to_string(), kernels, algo.description().to_string()]);
    }
    t
}

/// Median measured execution µs of (spec, algo) on a backend over
/// `iters` runs, via the descriptor → plan → execute lifecycle. `None`
/// when the backend does not support the pair (e.g. no AOT artifact).
///
/// Timings are caller wall-clock around [`Backend::execute`], i.e. the
/// serving-path cost including backend dispatch (for PJRT: tensor
/// staging plus the executor-thread round-trip) — not the bare kernel
/// time. On very small configs dispatch overhead can dominate, so
/// treat cross-algorithm ordering there with care.
fn measure_backend_us(
    backend: &dyn Backend,
    spec: &ConvSpec,
    algo: Algorithm,
    iters: usize,
) -> Option<f64> {
    if !backend.capabilities(spec, algo).is_supported() {
        return None;
    }
    let desc = ConvDescriptor::new(*spec).ok()?;
    let mut rng = Rng::new(0xCAFE);
    let input = Tensor::random(spec.n, spec.c, spec.h, spec.w, &mut rng, -1.0, 1.0);
    let filters = std::sync::Arc::new(Tensor::random(
        spec.m, spec.c, spec.kh, spec.kw, &mut rng, -1.0, 1.0,
    ));
    // Plan with the probe filters so algorithms with plan-time derived
    // weight state (packed tiled cuConv) are measured on the serving
    // code path.
    let plan = backend.plan_with_filters(&desc, algo, &filters).ok()?;
    let mut ws = Workspace::new();
    let [on, om, oh, ow] = spec.output_shape();
    let mut out = Tensor::zeros(on, om, oh, ow);
    // Warmup (PJRT compiles at plan time; this warms caches and grows
    // the reused workspace to its high-water size).
    backend.execute_into(&plan, &input, &filters, &mut ws, &mut out).ok()?;
    let mut times: Vec<f64> = (0..iters)
        .filter_map(|_| {
            let started = std::time::Instant::now();
            backend.execute_into(&plan, &input, &filters, &mut ws, &mut out).ok()?;
            Some(started.elapsed().as_secs_f64() * 1e6)
        })
        .collect();
    if times.is_empty() {
        return None;
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(times[times.len() / 2])
}

/// Tables 3–5: kernel times for the profiled configs.
///
/// `backend`: pass `Some` to add the measured column from real
/// executions through the descriptor → plan → execute API (PJRT
/// artifacts or the CPU reference backend).
pub fn table_kernels(table_no: u8, backend: Option<&dyn Backend>, iters: usize) -> Table {
    let filter = match table_no {
        3 => "1x1",
        4 => "3x3",
        _ => "5x5",
    };
    let mut t = Table::new(
        format!(
            "Table {table_no}: kernel times for the profiled {filter} configs (µs; \
             measured = our stack via the backend API, not V100-comparable)"
        ),
        &["config", "algorithm", "kernel", "paper us", "model us", "ours measured us"],
    );
    for label in paper::table_labels(table_no) {
        let spec = ConvSpec::from_table_label(label).unwrap();
        let rows: Vec<&paper::PaperRow> = paper::PAPER_ROWS
            .iter()
            .filter(|r| r.label == label)
            .collect();
        for row in rows {
            let model = gpumodel::predict(&spec, row.algo);
            let measured =
                backend.and_then(|b| measure_backend_us(b, &spec, row.algo, iters));
            // Per-kernel lines.
            for (i, pk) in row.kernels.iter().enumerate() {
                let model_us = model
                    .as_ref()
                    .and_then(|m| m.kernels.get(i))
                    .map(|k| fmt_us(k.us))
                    .unwrap_or_else(|| "-".into());
                t.row(vec![
                    if i == 0 { label.to_string() } else { String::new() },
                    if i == 0 { row.algo.name().to_string() } else { String::new() },
                    pk.kernel.to_string(),
                    fmt_us(pk.us),
                    model_us,
                    String::new(),
                ]);
            }
            // Total line (measured applies to the whole algorithm).
            t.row(vec![
                String::new(),
                String::new(),
                "Total".to_string(),
                fmt_us(row.total_us()),
                model
                    .as_ref()
                    .map(|m| fmt_us(m.total_us()))
                    .unwrap_or_else(|| "-".into()),
                measured.map(fmt_us).unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_five_networks() {
        let t = table1();
        assert_eq!(t.rows.len(), 5);
        let rendered = t.render();
        assert!(rendered.contains("GoogleNet"));
        assert!(rendered.contains("42"));
        assert!(rendered.contains("7x7x832"));
    }

    #[test]
    fn table2_lists_all_algorithms() {
        let t = table2();
        assert_eq!(t.rows.len(), Algorithm::ALL.len());
        assert!(t.render().contains("cuconv"));
        // Winograd row exists and reports 2 kernels for 3x3.
        assert!(t
            .rows
            .iter()
            .any(|r| r[0] == "winograd" && r[1] == "2"));
    }

    #[test]
    fn tables_3_to_5_have_paper_and_model_columns() {
        for no in [3u8, 4, 5] {
            let t = table_kernels(no, None, 1);
            assert!(!t.rows.is_empty(), "table {no} empty");
            // Totals must be present for every (config, algo).
            let totals = t.rows.iter().filter(|r| r[2] == "Total").count();
            let expected = paper::PAPER_ROWS.iter().filter(|r| r.table == no).count();
            assert_eq!(totals, expected, "table {no}");
        }
    }
}
