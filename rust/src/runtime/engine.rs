//! The PJRT engine: compile HLO text, cache executables, run them.
//!
//! Mirrors `/opt/xla-example/load_hlo.rs`: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All artifacts are lowered with
//! `return_tuple=True`, so results unwrap with `to_tuple1`.
//!
//! An [`Engine`] is deliberately `!Send` (the underlying handles are raw
//! pointers); cross-thread access goes through
//! [`executor`](crate::runtime::executor).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::manifest::{read_f32_bin, ConvArtifact, Manifest, ModelArtifact};
use crate::tensor::Tensor;

/// Timing breakdown of one execution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecTiming {
    /// Host→literal staging + argument prep.
    pub stage_seconds: f64,
    /// PJRT execute + device→host readback.
    pub exec_seconds: f64,
}

impl ExecTiming {
    pub fn total(&self) -> f64 {
        self.stage_seconds + self.exec_seconds
    }
}

/// PJRT client + lazily-compiled executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    compiles: usize,
}

impl Engine {
    /// Create a CPU PJRT engine over an artifact manifest.
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        Ok(Engine { client, manifest, cache: HashMap::new(), compiles: 0 })
    }

    /// Load the manifest from a directory and build the engine.
    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        Engine::new(Manifest::load(dir)?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of compilations performed (cache misses).
    pub fn compile_count(&self) -> usize {
        self.compiles
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<f64> {
        if self.cache.contains_key(name) {
            return Ok(0.0);
        }
        let file = self
            .artifact_file(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.manifest.path_of(&file);
        let start = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(wrap_xla)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(wrap_xla)
            .with_context(|| format!("compiling {name}"))?;
        self.cache.insert(name.to_string(), exe);
        self.compiles += 1;
        Ok(start.elapsed().as_secs_f64())
    }

    fn artifact_file(&self, name: &str) -> Option<String> {
        if let Some(c) = self.manifest.find_conv(name) {
            return Some(c.file.clone());
        }
        self.manifest.find_model(name).map(|m| m.file.clone())
    }

    /// Execute a conv artifact on (input, filters). Returns the output
    /// tensor and a timing breakdown.
    pub fn run_conv(
        &mut self,
        artifact: &ConvArtifact,
        input: &Tensor,
        filters: &Tensor,
    ) -> Result<(Tensor, ExecTiming)> {
        if input.shape() != artifact.spec.input_shape() {
            bail!(
                "input shape {:?} != artifact {:?}",
                input.shape(),
                artifact.spec.input_shape()
            );
        }
        if filters.shape() != artifact.spec.filter_shape() {
            bail!(
                "filter shape {:?} != artifact {:?}",
                filters.shape(),
                artifact.spec.filter_shape()
            );
        }
        self.ensure_compiled(&artifact.name)?;

        let t0 = Instant::now();
        let x = literal_from_tensor(input)?;
        let w = literal_from_tensor(filters)?;
        let stage_seconds = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let exe = self.cache.get(&artifact.name).expect("just compiled");
        let result = exe.execute::<xla::Literal>(&[x, w]).map_err(wrap_xla)?;
        let lit = result[0][0].to_literal_sync().map_err(wrap_xla)?;
        let out = lit.to_tuple1().map_err(wrap_xla)?;
        let data = out.to_vec::<f32>().map_err(wrap_xla)?;
        let exec_seconds = t1.elapsed().as_secs_f64();

        let [n, m, oh, ow] = artifact.spec.output_shape();
        if data.len() != n * m * oh * ow {
            bail!(
                "artifact {} returned {} elems, expected {}",
                artifact.name,
                data.len(),
                n * m * oh * ow
            );
        }
        Ok((Tensor::from_vec(n, m, oh, ow, data), ExecTiming { stage_seconds, exec_seconds }))
    }

    /// Execute a model artifact on an input batch `[B,3,H,W]` → logits.
    pub fn run_model(
        &mut self,
        artifact: &ModelArtifact,
        input: &[f32],
    ) -> Result<(Vec<f32>, ExecTiming)> {
        let n_in: usize = artifact.input_shape.iter().product();
        if input.len() != n_in {
            bail!(
                "model {} input has {} elems, expected {}",
                artifact.name,
                input.len(),
                n_in
            );
        }
        self.ensure_compiled(&artifact.name)?;

        let t0 = Instant::now();
        let dims: Vec<i64> = artifact.input_shape.iter().map(|&d| d as i64).collect();
        let x = xla::Literal::vec1(input).reshape(&dims).map_err(wrap_xla)?;
        let stage_seconds = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let exe = self.cache.get(&artifact.name).expect("just compiled");
        let result = exe.execute::<xla::Literal>(&[x]).map_err(wrap_xla)?;
        let lit = result[0][0].to_literal_sync().map_err(wrap_xla)?;
        let out = lit.to_tuple1().map_err(wrap_xla)?;
        let data = out.to_vec::<f32>().map_err(wrap_xla)?;
        let exec_seconds = t1.elapsed().as_secs_f64();

        let n_out: usize = artifact.output_shape.iter().product();
        if data.len() != n_out {
            bail!("model {} returned {} elems, expected {}", artifact.name, data.len(), n_out);
        }
        Ok((data, ExecTiming { stage_seconds, exec_seconds }))
    }

    /// Validate a model artifact against its AOT sample I/O pair.
    /// Returns the max absolute error.
    pub fn validate_model(&mut self, name: &str) -> Result<f32> {
        let artifact = self
            .manifest
            .find_model(name)
            .ok_or_else(|| anyhow!("unknown model '{name}'"))?
            .clone();
        let x = read_f32_bin(self.manifest.path_of(&artifact.sample_input))?;
        let want = read_f32_bin(self.manifest.path_of(&artifact.sample_output))?;
        let (got, _) = self.run_model(&artifact, &x)?;
        if got.len() != want.len() {
            bail!("sample output length mismatch");
        }
        Ok(got
            .iter()
            .zip(want.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }
}

/// Convert an NCHW tensor into an f32 literal of the same shape.
pub fn literal_from_tensor(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(t.data()).reshape(&dims).map_err(wrap_xla)
}

fn wrap_xla(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}
