//! The executor thread: cross-thread access to the `!Send` [`Engine`].
//!
//! One OS thread owns the PJRT client and the compiled-executable cache;
//! everyone else holds an [`ExecutorHandle`] (cheap to clone, `Send`)
//! and submits requests over an mpsc channel, receiving results on a
//! per-request oneshot channel. This is the same shape as a production
//! serving stack's per-accelerator submission queue, and it makes the
//! coordinator's worker pool trivially safe.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::runtime::engine::{Engine, ExecTiming};
use crate::runtime::manifest::Manifest;
use crate::tensor::Tensor;

enum Request {
    RunConv {
        name: String,
        input: Tensor,
        filters: Tensor,
        resp: mpsc::Sender<Result<(Tensor, ExecTiming)>>,
    },
    RunModel {
        name: String,
        input: Vec<f32>,
        resp: mpsc::Sender<Result<(Vec<f32>, ExecTiming)>>,
    },
    Warmup {
        names: Vec<String>,
        resp: mpsc::Sender<Result<f64>>,
    },
    ValidateModel {
        name: String,
        resp: mpsc::Sender<Result<f32>>,
    },
    CompileCount {
        resp: mpsc::Sender<usize>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the executor thread.
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: mpsc::Sender<Request>,
}

/// Owns the executor thread; joins it on drop.
pub struct ExecutorThread {
    handle: Option<JoinHandle<()>>,
    tx: mpsc::Sender<Request>,
}

/// Spawn the executor thread over an artifact manifest.
///
/// Returns the owning guard plus a cloneable handle. The engine (and
/// PJRT client) is created *on* the executor thread, since it must never
/// cross threads.
pub fn spawn_executor(manifest: Manifest) -> Result<(ExecutorThread, ExecutorHandle)> {
    let (tx, rx) = mpsc::channel::<Request>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let handle = std::thread::Builder::new()
        .name("pjrt-executor".into())
        .spawn(move || {
            let mut engine = match Engine::new(manifest) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::RunConv { name, input, filters, resp } => {
                        let r = engine
                            .manifest()
                            .find_conv(&name)
                            .cloned()
                            .ok_or_else(|| anyhow!("unknown conv artifact '{name}'"))
                            .and_then(|a| engine.run_conv(&a, &input, &filters));
                        let _ = resp.send(r);
                    }
                    Request::RunModel { name, input, resp } => {
                        let r = engine
                            .manifest()
                            .find_model(&name)
                            .cloned()
                            .ok_or_else(|| anyhow!("unknown model artifact '{name}'"))
                            .and_then(|a| engine.run_model(&a, &input));
                        let _ = resp.send(r);
                    }
                    Request::Warmup { names, resp } => {
                        let mut total = 0.0;
                        let mut result = Ok(());
                        for n in &names {
                            match engine.ensure_compiled(n) {
                                Ok(secs) => total += secs,
                                Err(e) => {
                                    result = Err(e);
                                    break;
                                }
                            }
                        }
                        let _ = resp.send(result.map(|_| total));
                    }
                    Request::ValidateModel { name, resp } => {
                        let _ = resp.send(engine.validate_model(&name));
                    }
                    Request::CompileCount { resp } => {
                        let _ = resp.send(engine.compile_count());
                    }
                    Request::Shutdown => break,
                }
            }
        })?;
    ready_rx
        .recv()
        .map_err(|_| anyhow!("executor thread died during startup"))??;
    let guard = ExecutorThread { handle: Some(handle), tx: tx.clone() };
    Ok((guard, ExecutorHandle { tx }))
}

impl ExecutorHandle {
    /// Execute a conv artifact by name.
    pub fn run_conv(
        &self,
        name: &str,
        input: Tensor,
        filters: Tensor,
    ) -> Result<(Tensor, ExecTiming)> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::RunConv { name: name.to_string(), input, filters, resp })
            .map_err(|_| anyhow!("executor thread is gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped the request"))?
    }

    /// Execute a model artifact by name.
    pub fn run_model(&self, name: &str, input: Vec<f32>) -> Result<(Vec<f32>, ExecTiming)> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::RunModel { name: name.to_string(), input, resp })
            .map_err(|_| anyhow!("executor thread is gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped the request"))?
    }

    /// Pre-compile a set of artifacts; returns total compile seconds.
    pub fn warmup(&self, names: &[String]) -> Result<f64> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::Warmup { names: names.to_vec(), resp })
            .map_err(|_| anyhow!("executor thread is gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped the request"))?
    }

    /// Number of engine compilations so far (cache misses) — flat across
    /// plan-reuse executions.
    pub fn compile_count(&self) -> Result<usize> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::CompileCount { resp })
            .map_err(|_| anyhow!("executor thread is gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped the request"))
    }

    /// Run a model's AOT sample I/O pair; returns max abs error.
    pub fn validate_model(&self, name: &str) -> Result<f32> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Request::ValidateModel { name: name.to_string(), resp })
            .map_err(|_| anyhow!("executor thread is gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped the request"))?
    }
}

impl Drop for ExecutorThread {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
