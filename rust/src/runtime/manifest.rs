//! `artifacts/manifest.json` parsing — the contract between
//! `python/compile/aot.py` (writer) and this runtime (reader).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::conv::ConvSpec;
use crate::util::json::{self, Json};

/// A per-configuration convolution executable.
#[derive(Debug, Clone)]
pub struct ConvArtifact {
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Algorithm name (matches `crate::algo` and the Python registry).
    pub algo: String,
    /// Paper-style label `[HW]-[N]-[K]-[M]-[C]`.
    pub label: String,
    pub spec: ConvSpec,
}

/// An end-to-end model executable with baked weights.
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    pub name: String,
    pub file: String,
    pub model: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    /// Raw-f32 sample input/output pair (relative paths) computed with
    /// the independent reference algorithm at AOT time.
    pub sample_input: String,
    pub sample_output: String,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub convs: Vec<ConvArtifact>,
    pub models: Vec<ModelArtifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text (dir recorded for relative paths).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let version = root
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut convs = Vec::new();
        for c in root.get("convs").and_then(Json::as_arr).unwrap_or(&[]) {
            convs.push(parse_conv(c)?);
        }
        let mut models = Vec::new();
        for m in root.get("models").and_then(Json::as_arr).unwrap_or(&[]) {
            models.push(parse_model(m)?);
        }
        Ok(Manifest { dir, convs, models })
    }

    /// Absolute path of an artifact-relative file.
    pub fn path_of(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }

    pub fn find_conv(&self, name: &str) -> Option<&ConvArtifact> {
        self.convs.iter().find(|c| c.name == name)
    }

    /// Conv artifacts for a given label, one per lowered algorithm.
    pub fn convs_for_label(&self, label: &str) -> Vec<&ConvArtifact> {
        self.convs.iter().filter(|c| c.label == label).collect()
    }

    pub fn find_model(&self, name: &str) -> Option<&ModelArtifact> {
        self.models.iter().find(|m| m.name == name)
    }

    /// Model executables of one family, sorted by batch size — the
    /// coordinator's batcher picks the largest batch ≤ queue depth.
    pub fn model_family(&self, model: &str) -> Vec<&ModelArtifact> {
        let mut v: Vec<&ModelArtifact> =
            self.models.iter().filter(|m| m.model == model).collect();
        v.sort_by_key(|m| m.batch);
        v
    }
}

fn field<'a>(v: &'a Json, key: &str) -> Result<&'a Json> {
    v.get(key).ok_or_else(|| anyhow!("manifest entry missing '{key}'"))
}

fn str_field(v: &Json, key: &str) -> Result<String> {
    Ok(field(v, key)?
        .as_str()
        .ok_or_else(|| anyhow!("'{key}' is not a string"))?
        .to_string())
}

fn usize_field(v: &Json, key: &str) -> Result<usize> {
    field(v, key)?
        .as_usize()
        .ok_or_else(|| anyhow!("'{key}' is not a non-negative integer"))
}

fn shape_field(v: &Json, key: &str) -> Result<Vec<usize>> {
    field(v, key)?
        .as_arr()
        .ok_or_else(|| anyhow!("'{key}' is not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in '{key}'")))
        .collect()
}

fn parse_conv(v: &Json) -> Result<ConvArtifact> {
    let spec_json = field(v, "spec")?;
    let spec = ConvSpec {
        n: usize_field(spec_json, "n")?,
        c: usize_field(spec_json, "c")?,
        h: usize_field(spec_json, "h")?,
        w: usize_field(spec_json, "w")?,
        m: usize_field(spec_json, "m")?,
        kh: usize_field(spec_json, "kh")?,
        kw: usize_field(spec_json, "kw")?,
        stride: usize_field(spec_json, "stride")?,
        pad_h: usize_field(spec_json, "pad_h")?,
        pad_w: usize_field(spec_json, "pad_w")?,
    };
    if !spec.is_valid() {
        bail!("invalid conv spec in manifest: {spec}");
    }
    Ok(ConvArtifact {
        name: str_field(v, "name")?,
        file: str_field(v, "file")?,
        algo: str_field(v, "algo")?,
        label: str_field(v, "label")?,
        spec,
    })
}

fn parse_model(v: &Json) -> Result<ModelArtifact> {
    Ok(ModelArtifact {
        name: str_field(v, "name")?,
        file: str_field(v, "file")?,
        model: str_field(v, "model")?,
        batch: usize_field(v, "batch")?,
        input_shape: shape_field(v, "input_shape")?,
        output_shape: shape_field(v, "output_shape")?,
        sample_input: str_field(v, "sample_input")?,
        sample_output: str_field(v, "sample_output")?,
    })
}

/// Read a raw little-endian f32 binary file (the sample I/O format).
pub fn read_f32_bin(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    if bytes.len() % 4 != 0 {
        bail!("f32 bin file has non-multiple-of-4 length {}", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "convs": [
        {"name": "conv_7-1-1-32-832_cuconv", "file": "a.hlo.txt",
         "algo": "cuconv", "label": "7-1-1-32-832",
         "spec": {"n":1,"c":832,"h":7,"w":7,"m":32,"kh":1,"kw":1,
                  "stride":1,"pad_h":0,"pad_w":0},
         "input_shapes": [[1,832,7,7],[32,832,1,1]],
         "output_shape": [1,32,7,7]}
      ],
      "models": [
        {"name": "minisqueezenet_b2", "file": "m.hlo.txt",
         "model": "minisqueezenet", "batch": 2,
         "input_shape": [2,3,32,32], "output_shape": [2,10],
         "sample_input": "io/in.bin", "sample_output": "io/out.bin",
         "param_count": 8258}
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.convs.len(), 1);
        assert_eq!(m.models.len(), 1);
        let c = &m.convs[0];
        assert_eq!(c.algo, "cuconv");
        assert_eq!(c.spec.c, 832);
        assert_eq!(c.spec.fig_label(), "7-32-832");
        let md = &m.models[0];
        assert_eq!(md.batch, 2);
        assert_eq!(md.input_shape, vec![2, 3, 32, 32]);
    }

    #[test]
    fn lookup_helpers() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/x")).unwrap();
        assert!(m.find_conv("conv_7-1-1-32-832_cuconv").is_some());
        assert!(m.find_conv("nope").is_none());
        assert_eq!(m.convs_for_label("7-1-1-32-832").len(), 1);
        assert_eq!(m.model_family("minisqueezenet").len(), 1);
        assert_eq!(m.path_of("a.hlo.txt"), PathBuf::from("/x/a.hlo.txt"));
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, PathBuf::from("/x")).is_err());
    }

    #[test]
    fn rejects_invalid_spec() {
        let bad = SAMPLE.replace("\"h\":7", "\"h\":0");
        assert!(Manifest::parse(&bad, PathBuf::from("/x")).is_err());
    }

    #[test]
    fn f32_bin_roundtrip() {
        let dir = std::env::temp_dir().join("cuconv_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32_bin(&p).unwrap(), vals);
        std::fs::write(&p, [0u8; 5]).unwrap();
        assert!(read_f32_bin(&p).is_err());
    }
}
