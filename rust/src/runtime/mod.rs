//! Layer-3 runtime: load and execute the AOT artifacts via PJRT.
//!
//! Manifest parsing is always available; the engine/executor (and their
//! `xla` dependency) are gated behind the `pjrt` cargo feature so the
//! default build works offline. Convolution call sites should not use
//! this module directly — go through
//! [`backend::PjrtBackend`](crate::backend) instead.
//!
//! The build-time Python side (`python/compile/aot.py`) lowers every
//! kernel/model to HLO **text** in `artifacts/`; this module is the only
//! place that touches the `xla` crate:
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (conv executables
//!   with their [`ConvSpec`](crate::conv::ConvSpec), model executables
//!   with sample I/O for end-to-end validation).
//! * [`engine`] — the PJRT CPU client wrapper: HLO-text → compile →
//!   execute, with an executable cache and literal↔[`Tensor`](crate::tensor::Tensor)
//!   conversion. `xla` handles are raw pointers (`!Send`), so an
//!   [`Engine`] must stay on one thread.
//! * [`executor`] — the threading answer: a dedicated executor thread
//!   owns the [`Engine`]; [`ExecutorHandle`] is a cheap, cloneable,
//!   `Send` handle the coordinator's workers submit work through. This
//!   mirrors production serving stacks where a single submission queue
//!   fronts each accelerator.

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod executor;
pub mod manifest;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, ExecTiming};
#[cfg(feature = "pjrt")]
pub use executor::{spawn_executor, ExecutorHandle};
pub use manifest::{ConvArtifact, Manifest, ModelArtifact};

use std::path::PathBuf;

/// Default artifact directory: `$CUCONV_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("CUCONV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
