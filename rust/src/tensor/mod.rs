//! NCHW tensors.
//!
//! The paper's implementation (and this reproduction) works on NCHW
//! f32 tensors: `N` volumes of `C` channels of `H×W` planes, with the `W`
//! (X) axis contiguous in memory — the layout whose coalescing behaviour
//! §3 of the paper analyzes.

use crate::util::rng::Rng;

/// A dense f32 tensor in NCHW layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(n: usize, c: usize, h: usize, w: usize) -> Tensor {
        Tensor { n, c, h, w, data: vec![0.0; n * c * h * w] }
    }

    /// Tensor filled with a constant.
    pub fn full(n: usize, c: usize, h: usize, w: usize, v: f32) -> Tensor {
        Tensor { n, c, h, w, data: vec![v; n * c * h * w] }
    }

    /// Tensor from existing data; length must match the shape.
    pub fn from_vec(n: usize, c: usize, h: usize, w: usize, data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), n * c * h * w, "shape/data mismatch");
        Tensor { n, c, h, w, data }
    }

    /// Uniform random tensor in `[lo, hi)` from a seeded PRNG.
    pub fn random(n: usize, c: usize, h: usize, w: usize, rng: &mut Rng, lo: f32, hi: f32) -> Tensor {
        let mut t = Tensor::zeros(n, c, h, w);
        rng.fill_uniform(&mut t.data, lo, hi);
        t
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Shape as `[n, c, h, w]`.
    pub fn shape(&self) -> [usize; 4] {
        [self.n, self.c, self.h, self.w]
    }

    /// Flat NCHW offset of `(n, c, y, x)`.
    #[inline]
    pub fn offset(&self, n: usize, c: usize, y: usize, x: usize) -> usize {
        ((n * self.c + c) * self.h + y) * self.w + x
    }

    #[inline]
    pub fn at(&self, n: usize, c: usize, y: usize, x: usize) -> f32 {
        self.data[self.offset(n, c, y, x)]
    }

    #[inline]
    pub fn at_mut(&mut self, n: usize, c: usize, y: usize, x: usize) -> &mut f32 {
        let i = self.offset(n, c, y, x);
        &mut self.data[i]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Maximum absolute difference vs another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape(), other.shape(), "shape mismatch");
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 error vs a reference tensor: ‖a−b‖₂ / max(‖b‖₂, ε).
    pub fn rel_l2_error(&self, reference: &Tensor) -> f32 {
        assert_eq!(self.shape(), reference.shape(), "shape mismatch");
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(reference.data.iter()) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        (num.sqrt() / den.sqrt().max(1e-12)) as f32
    }

    /// True if all elements are within `atol + rtol*|ref|` of the reference.
    pub fn allclose(&self, reference: &Tensor, rtol: f32, atol: f32) -> bool {
        assert_eq!(self.shape(), reference.shape(), "shape mismatch");
        self.data
            .iter()
            .zip(reference.data.iter())
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// Zero-pad the H and W dimensions by `ph`/`pw` on each side.
    pub fn pad_hw(&self, ph: usize, pw: usize) -> Tensor {
        let mut out = Tensor::zeros(self.n, self.c, self.h + 2 * ph, self.w + 2 * pw);
        for n in 0..self.n {
            for c in 0..self.c {
                for y in 0..self.h {
                    let src = self.offset(n, c, y, 0);
                    let dst = out.offset(n, c, y + ph, pw);
                    out.data[dst..dst + self.w]
                        .copy_from_slice(&self.data[src..src + self.w]);
                }
            }
        }
        out
    }

    /// Reinterpret to a new 4D shape with the same number of elements.
    pub fn reshape(mut self, n: usize, c: usize, h: usize, w: usize) -> Tensor {
        assert_eq!(self.len(), n * c * h * w, "reshape element-count mismatch");
        self.n = n;
        self.c = c;
        self.h = h;
        self.w = w;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offsets_are_nchw() {
        let t = Tensor::zeros(2, 3, 4, 5);
        assert_eq!(t.offset(0, 0, 0, 0), 0);
        assert_eq!(t.offset(0, 0, 0, 1), 1); // x contiguous
        assert_eq!(t.offset(0, 0, 1, 0), 5); // y stride = w
        assert_eq!(t.offset(0, 1, 0, 0), 20); // c stride = h*w
        assert_eq!(t.offset(1, 0, 0, 0), 60); // n stride = c*h*w
    }

    #[test]
    fn at_and_at_mut_roundtrip() {
        let mut t = Tensor::zeros(1, 2, 3, 4);
        *t.at_mut(0, 1, 2, 3) = 7.5;
        assert_eq!(t.at(0, 1, 2, 3), 7.5);
        assert_eq!(t.data().iter().filter(|&&v| v != 0.0).count(), 1);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_checks_length() {
        Tensor::from_vec(1, 1, 2, 2, vec![0.0; 5]);
    }

    #[test]
    fn random_is_deterministic_and_bounded() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = Tensor::random(1, 2, 3, 4, &mut r1, -1.0, 1.0);
        let b = Tensor::random(1, 2, 3, 4, &mut r2, -1.0, 1.0);
        assert_eq!(a, b);
        assert!(a.data().iter().all(|v| (-1.0..1.0).contains(v)));
    }

    #[test]
    fn pad_hw_places_values_centered() {
        let mut t = Tensor::zeros(1, 1, 2, 2);
        *t.at_mut(0, 0, 0, 0) = 1.0;
        *t.at_mut(0, 0, 1, 1) = 2.0;
        let p = t.pad_hw(1, 1);
        assert_eq!(p.shape(), [1, 1, 4, 4]);
        assert_eq!(p.at(0, 0, 1, 1), 1.0);
        assert_eq!(p.at(0, 0, 2, 2), 2.0);
        assert_eq!(p.at(0, 0, 0, 0), 0.0);
        let sum: f32 = p.data().iter().sum();
        assert_eq!(sum, 3.0);
    }

    #[test]
    fn allclose_and_errors() {
        let a = Tensor::full(1, 1, 2, 2, 1.0);
        let mut b = a.clone();
        *b.at_mut(0, 0, 0, 0) = 1.0 + 1e-6;
        assert!(b.allclose(&a, 1e-5, 1e-5));
        assert!(b.max_abs_diff(&a) > 0.0);
        assert!(b.rel_l2_error(&a) < 1e-5);
        *b.at_mut(0, 0, 0, 0) = 2.0;
        assert!(!b.allclose(&a, 1e-3, 1e-3));
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(1, 1, 2, 6, (0..12).map(|i| i as f32).collect());
        let r = t.clone().reshape(1, 3, 2, 2);
        assert_eq!(r.shape(), [1, 3, 2, 2]);
        assert_eq!(r.data(), t.data());
    }

    #[test]
    #[should_panic(expected = "reshape element-count mismatch")]
    fn reshape_checks_count() {
        Tensor::zeros(1, 1, 2, 2).reshape(1, 1, 3, 3);
    }
}
