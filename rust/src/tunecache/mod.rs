//! Persistent autotune/plan cache: tuned decisions (`algo_find`
//! rankings, `find_tile` winners, measured timings) serialized to a
//! versioned on-disk JSON file so a server restart replays yesterday's
//! measurements instead of re-paying the sweep.
//!
//! cuDNN's central lesson is that expensive algorithm decisions are
//! made once at plan time and amortized across every call; this module
//! extends the amortization across *processes*. The file is keyed by a
//! device fingerprint (effective thread count — which already folds the
//! `CUCONV_CPU_THREADS` override — plus the crate version and a cache
//! schema version). Any mismatch, truncation, or unknown key degrades
//! to re-tuning: load never panics and never errors, it just returns a
//! cache that misses (logging and counting each degradation).
//!
//! Determinism contract: [`TuneCache::to_json`] emits entries sorted by
//! spec and the JSON writer emits sorted keys, so a freshly tuned run
//! round-trips **bit-identically** through save → load → save.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::algo::{Algorithm, AutotuneEntry};
use crate::conv::ConvSpec;
use crate::cpuref::gemm::default_threads;
use crate::cpuref::pack::TileShape;
use crate::util::json::{self, Json};

/// On-disk format version. Bump on any incompatible layout change; a
/// loader seeing a different version discards the file (counted as a
/// degradation) rather than guessing.
pub const SCHEMA_VERSION: u64 = 1;

/// Process-global count of timing measurements (one per candidate put
/// through a timed benchmark loop by `algo_find` or `find_tile`). The
/// warm-start proof: planning against a populated cache must leave this
/// counter untouched.
static MEASUREMENTS: AtomicUsize = AtomicUsize::new(0);

/// Record `n` timing measurements. Called by the measuring paths
/// (`algo_find` per timed algorithm candidate, `find_tile` per tile
/// candidate); never by cache hits.
pub fn note_measurements(n: usize) {
    MEASUREMENTS.fetch_add(n, Ordering::Relaxed);
}

/// Total timing measurements this process has performed. Tests and the
/// CI warm-start smoke assert a **zero delta** across a warm plan.
pub fn measurement_count() -> usize {
    MEASUREMENTS.load(Ordering::Relaxed)
}

/// The device identity a cache file is valid for. Tuned timings are
/// meaningless on a different machine shape, so a fingerprint mismatch
/// discards the file wholesale.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fingerprint {
    /// Effective worker thread count ([`default_threads`]), which
    /// already folds in the `CUCONV_CPU_THREADS` env override and the
    /// programmatic override — the knob that most changes measured
    /// timings on this substrate.
    pub threads: usize,
    /// Crate version the file was written by; tuning heuristics and
    /// kernels move between releases.
    pub crate_version: String,
}

impl Fingerprint {
    /// The fingerprint of this process, right now.
    pub fn current() -> Fingerprint {
        Fingerprint { threads: default_threads(), crate_version: crate::VERSION.to_string() }
    }
}

/// Cached tuning decisions for one [`ConvSpec`].
#[derive(Debug, Clone, Default, PartialEq)]
struct Entry {
    /// `algo_find` ranking: (algorithm, score in µs, workspace bytes),
    /// best first.
    algos: Option<Vec<(Algorithm, f64, usize)>>,
    /// `find_tile` winner and its measured p50 in µs.
    tile: Option<(TileShape, f64)>,
}

/// The persistent autotune cache. Thread-safe; share one behind an
/// `Arc` between a [`CpuRefBackend`](crate::backend::CpuRefBackend)
/// and a [`NetPlanner`](crate::net::NetPlanner) so tile and algorithm
/// decisions land in the same file.
#[derive(Debug)]
pub struct TuneCache {
    fingerprint: Fingerprint,
    entries: Mutex<HashMap<ConvSpec, Entry>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    degraded: AtomicUsize,
}

impl Default for TuneCache {
    fn default() -> TuneCache {
        TuneCache::new()
    }
}

impl TuneCache {
    /// An empty cache stamped with the current process fingerprint.
    pub fn new() -> TuneCache {
        TuneCache {
            fingerprint: Fingerprint::current(),
            entries: Mutex::new(HashMap::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            degraded: AtomicUsize::new(0),
        }
    }

    /// Load a cache from `path`. **Never fails**: an unreadable file,
    /// corrupt or truncated JSON, a schema/crate-version or fingerprint
    /// mismatch all log one line, count a degradation, and return an
    /// empty cache (so every lookup misses and the caller re-tunes).
    /// Individually malformed entries are skipped, keeping the rest.
    pub fn load(path: impl AsRef<Path>) -> TuneCache {
        let path = path.as_ref();
        let cache = TuneCache::new();
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("tunecache: cannot read {}: {e}; starting cold", path.display());
                cache.degraded.fetch_add(1, Ordering::Relaxed);
                return cache;
            }
        };
        let doc = match json::parse(&text) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("tunecache: {}: {e}; starting cold", path.display());
                cache.degraded.fetch_add(1, Ordering::Relaxed);
                return cache;
            }
        };
        cache.absorb(&doc, &path.display().to_string());
        cache
    }

    /// Rebuild state from a parsed document (the load path after I/O
    /// and parsing; exposed for round-trip tests). Returns `self`
    /// unchanged-but-empty on any header mismatch.
    fn absorb(&self, doc: &Json, origin: &str) {
        let degrade = |msg: &str| {
            eprintln!("tunecache: {origin}: {msg}; starting cold");
            self.degraded.fetch_add(1, Ordering::Relaxed);
        };
        match doc.get("schema_version").and_then(Json::as_usize) {
            Some(v) if v as u64 == SCHEMA_VERSION => {}
            v => return degrade(&format!(
                "schema_version {v:?} != supported {SCHEMA_VERSION}"
            )),
        }
        match doc.get("crate_version").and_then(Json::as_str) {
            Some(v) if v == self.fingerprint.crate_version => {}
            v => return degrade(&format!(
                "crate_version {v:?} != running {}",
                self.fingerprint.crate_version
            )),
        }
        match doc.get("fingerprint").and_then(|f| f.get("threads")).and_then(Json::as_usize) {
            Some(t) if t == self.fingerprint.threads => {}
            t => return degrade(&format!(
                "fingerprint threads {t:?} != current {}",
                self.fingerprint.threads
            )),
        }
        let Some(rows) = doc.get("entries").and_then(Json::as_arr) else {
            return degrade("'entries' missing or not an array");
        };
        let mut map = self.entries.lock().unwrap();
        for row in rows {
            match parse_entry(row) {
                Some((spec, entry)) => {
                    map.insert(spec, entry);
                }
                None => {
                    eprintln!("tunecache: {origin}: skipping malformed entry");
                    self.degraded.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Build a cache from an in-memory document (round-trip testing).
    pub fn from_json(doc: &Json) -> TuneCache {
        let cache = TuneCache::new();
        cache.absorb(doc, "<memory>");
        cache
    }

    /// Serialize every entry, sorted by spec for a deterministic byte
    /// stream (the JSON writer already sorts object keys).
    pub fn to_json(&self) -> Json {
        let map = self.entries.lock().unwrap();
        let mut specs: Vec<&ConvSpec> = map.keys().collect();
        specs.sort_by_key(|s| {
            (s.n, s.c, s.h, s.w, s.m, s.kh, s.kw, s.stride, s.pad_h, s.pad_w)
        });
        let rows = specs
            .iter()
            .map(|spec| {
                let entry = &map[*spec];
                let mut pairs = vec![("spec", spec_json(spec))];
                if let Some(algos) = &entry.algos {
                    pairs.push((
                        "algos",
                        Json::arr(
                            algos
                                .iter()
                                .map(|(a, score, ws)| {
                                    Json::obj(vec![
                                        ("algo", Json::str(a.name())),
                                        ("score_us", Json::num(*score)),
                                        ("workspace_bytes", Json::num(*ws as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
                if let Some((tile, p50)) = &entry.tile {
                    pairs.push((
                        "tile",
                        Json::obj(vec![
                            ("mr", Json::num(tile.mr() as f64)),
                            ("nr", Json::num(tile.nr() as f64)),
                            ("p50_us", Json::num(*p50)),
                        ]),
                    ));
                }
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![
            ("schema_version", Json::num(SCHEMA_VERSION as f64)),
            ("crate_version", Json::str(&self.fingerprint.crate_version)),
            (
                "fingerprint",
                Json::obj(vec![("threads", Json::num(self.fingerprint.threads as f64))]),
            ),
            ("entries", Json::arr(rows)),
        ])
    }

    /// Write the cache to `path` (pretty-printed, trailing newline).
    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
    }

    /// Cached `algo_find` ranking for `spec`, if present (counts a hit
    /// or a miss).
    pub fn lookup_algos(&self, spec: &ConvSpec) -> Option<Vec<AutotuneEntry>> {
        let found = self.entries.lock().unwrap().get(spec).and_then(|e| e.algos.clone());
        match found {
            Some(rows) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(
                    rows.into_iter()
                        .map(|(algo, score_us, workspace_bytes)| AutotuneEntry {
                            algo,
                            score_us,
                            workspace_bytes,
                        })
                        .collect(),
                )
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record a freshly measured `algo_find` ranking for `spec`.
    pub fn record_algos(&self, spec: &ConvSpec, entries: &[AutotuneEntry]) {
        let rows = entries.iter().map(|e| (e.algo, e.score_us, e.workspace_bytes)).collect();
        self.entries.lock().unwrap().entry(*spec).or_default().algos = Some(rows);
    }

    /// Cached `find_tile` winner for `spec`, if present (counts a hit
    /// or a miss).
    pub fn lookup_tile(&self, spec: &ConvSpec) -> Option<TileShape> {
        let found = self.entries.lock().unwrap().get(spec).and_then(|e| e.tile);
        match found {
            Some((tile, _)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(tile)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record a freshly measured tile winner for `spec`.
    pub fn record_tile(&self, spec: &ConvSpec, tile: TileShape, p50_us: f64) {
        self.entries.lock().unwrap().entry(*spec).or_default().tile = Some((tile, p50_us));
    }

    /// Number of specs with at least one cached decision.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups answered from the cache.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that fell through to measurement.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Degradations survived (unreadable/corrupt file, version or
    /// fingerprint mismatch, malformed entries skipped).
    pub fn degraded(&self) -> usize {
        self.degraded.load(Ordering::Relaxed)
    }
}

fn spec_json(spec: &ConvSpec) -> Json {
    Json::obj(vec![
        ("n", Json::num(spec.n as f64)),
        ("c", Json::num(spec.c as f64)),
        ("h", Json::num(spec.h as f64)),
        ("w", Json::num(spec.w as f64)),
        ("m", Json::num(spec.m as f64)),
        ("kh", Json::num(spec.kh as f64)),
        ("kw", Json::num(spec.kw as f64)),
        ("stride", Json::num(spec.stride as f64)),
        ("pad_h", Json::num(spec.pad_h as f64)),
        ("pad_w", Json::num(spec.pad_w as f64)),
    ])
}

fn parse_spec(doc: &Json) -> Option<ConvSpec> {
    let field = |k: &str| doc.get(k).and_then(Json::as_usize);
    let spec = ConvSpec {
        n: field("n")?,
        c: field("c")?,
        h: field("h")?,
        w: field("w")?,
        m: field("m")?,
        kh: field("kh")?,
        kw: field("kw")?,
        stride: field("stride")?,
        pad_h: field("pad_h")?,
        pad_w: field("pad_w")?,
    };
    spec.is_valid().then_some(spec)
}

fn parse_entry(row: &Json) -> Option<(ConvSpec, Entry)> {
    let spec = parse_spec(row.get("spec")?)?;
    let mut entry = Entry::default();
    if let Some(rows) = row.get("algos") {
        let rows = rows.as_arr()?;
        let mut algos = Vec::with_capacity(rows.len());
        for r in rows {
            let algo = Algorithm::from_name(r.get("algo")?.as_str()?)?;
            let score = r.get("score_us")?.as_f64()?;
            if !score.is_finite() || score < 0.0 {
                return None;
            }
            let ws = r.get("workspace_bytes")?.as_usize()?;
            algos.push((algo, score, ws));
        }
        entry.algos = Some(algos);
    }
    if let Some(t) = row.get("tile") {
        let tile = TileShape::of(t.get("mr")?.as_usize()?, t.get("nr")?.as_usize()?)?;
        let p50 = t.get("p50_us")?.as_f64()?;
        if !p50.is_finite() || p50 < 0.0 {
            return None;
        }
        entry.tile = Some((tile, p50));
    }
    if entry.algos.is_none() && entry.tile.is_none() {
        return None;
    }
    Some((spec, entry))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::AutotuneEntry;

    fn populated() -> TuneCache {
        let cache = TuneCache::new();
        let s1 = ConvSpec::paper(7, 1, 1, 32, 832);
        let s2 = ConvSpec::paper(14, 2, 3, 64, 64);
        cache.record_algos(
            &s1,
            &[
                AutotuneEntry {
                    algo: Algorithm::CuConv,
                    score_us: 12.5,
                    workspace_bytes: 0,
                },
                AutotuneEntry {
                    algo: Algorithm::Direct,
                    score_us: 31.0,
                    workspace_bytes: 0,
                },
            ],
        );
        cache.record_tile(&s1.with_batch(1), TileShape::of(4, 8).unwrap(), 9.75);
        cache.record_algos(
            &s2,
            &[AutotuneEntry {
                algo: Algorithm::GemmExplicit,
                score_us: 44.0,
                workspace_bytes: 1024,
            }],
        );
        cache
    }

    #[test]
    fn roundtrip_is_bit_identical() {
        let cache = populated();
        let first = cache.to_json().to_string_pretty() + "\n";
        let reloaded = TuneCache::from_json(&json::parse(&first).unwrap());
        assert_eq!(reloaded.degraded(), 0, "clean file must load cleanly");
        assert_eq!(reloaded.len(), cache.len());
        let second = reloaded.to_json().to_string_pretty() + "\n";
        assert_eq!(first, second, "save -> load -> save must be bit-identical");
    }

    #[test]
    fn lookups_count_hits_and_misses() {
        let cache = populated();
        let s1 = ConvSpec::paper(7, 1, 1, 32, 832);
        let ranked = cache.lookup_algos(&s1).expect("recorded ranking");
        assert_eq!(ranked[0].algo, Algorithm::CuConv);
        assert_eq!(ranked[0].score_us, 12.5);
        assert!(cache.lookup_tile(&s1.with_batch(1)).is_some());
        assert!(cache.lookup_algos(&ConvSpec::paper(3, 1, 1, 4, 4)).is_none());
        assert!(cache.lookup_tile(&s1).is_none(), "tile keyed at batch 1 only");
        assert_eq!(cache.hits(), 2);
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn save_and_load_through_a_real_file() {
        let cache = populated();
        let dir = std::env::temp_dir();
        let path = dir.join(format!("cuconv_tunecache_test_{}.json", std::process::id()));
        cache.save(&path).unwrap();
        let loaded = TuneCache::load(&path);
        assert_eq!(loaded.degraded(), 0);
        assert_eq!(loaded.len(), cache.len());
        assert_eq!(
            loaded.to_json().to_string_pretty(),
            cache.to_json().to_string_pretty()
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_degrades_to_cold() {
        let loaded = TuneCache::load("/nonexistent/tunecache.json");
        assert!(loaded.is_empty());
        assert_eq!(loaded.degraded(), 1);
        // And the cold cache still misses (counted), never panics.
        assert!(loaded.lookup_algos(&ConvSpec::paper(7, 1, 1, 32, 832)).is_none());
        assert_eq!(loaded.misses(), 1);
    }

    #[test]
    fn corrupt_and_truncated_json_degrade_to_cold() {
        let good = populated().to_json().to_string_pretty();
        for text in ["{not json", &good[..good.len() / 2], "", "[1, 2, 3]"] {
            let doc = json::parse(text);
            let cache = match doc {
                Ok(d) => TuneCache::from_json(&d),
                Err(_) => {
                    // The load path counts the parse failure; emulate it.
                    let c = TuneCache::new();
                    c.degraded.fetch_add(1, Ordering::Relaxed);
                    c
                }
            };
            assert!(cache.is_empty(), "malformed input {text:?} must yield a cold cache");
            assert!(cache.degraded() > 0, "degradation must be counted for {text:?}");
        }
    }

    #[test]
    fn schema_version_bump_discards_the_file() {
        let mut doc = populated().to_json();
        if let Json::Obj(map) = &mut doc {
            map.insert("schema_version".into(), Json::num(SCHEMA_VERSION as f64 + 1.0));
        }
        let cache = TuneCache::from_json(&doc);
        assert!(cache.is_empty());
        assert_eq!(cache.degraded(), 1);
    }

    #[test]
    fn crate_version_mismatch_discards_the_file() {
        let mut doc = populated().to_json();
        if let Json::Obj(map) = &mut doc {
            map.insert("crate_version".into(), Json::str("0.0.0-other"));
        }
        let cache = TuneCache::from_json(&doc);
        assert!(cache.is_empty());
        assert_eq!(cache.degraded(), 1);
    }

    #[test]
    fn fingerprint_mismatch_discards_the_file() {
        let mut doc = populated().to_json();
        if let Json::Obj(map) = &mut doc {
            map.insert(
                "fingerprint".into(),
                Json::obj(vec![("threads", Json::num(default_threads() as f64 + 7.0))]),
            );
        }
        let cache = TuneCache::from_json(&doc);
        assert!(cache.is_empty());
        assert_eq!(cache.degraded(), 1);
        // A subsequent lookup is a counted miss — the re-tune path.
        assert!(cache.lookup_tile(&ConvSpec::paper(7, 1, 1, 32, 832)).is_none());
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn unknown_algo_or_tile_skips_only_that_entry() {
        let mut doc = populated().to_json();
        if let Json::Obj(map) = &mut doc {
            let Some(Json::Arr(rows)) = map.get_mut("entries") else { panic!() };
            let n = rows.len();
            // Poison the first entry's algorithm name and append an
            // entry with an impossible tile; both must be skipped while
            // the rest survive.
            if let Json::Obj(row) = &mut rows[0] {
                if let Some(Json::Arr(algos)) = row.get_mut("algos") {
                    if let Json::Obj(a) = &mut algos[0] {
                        a.insert("algo".into(), Json::str("quantum_conv"));
                    }
                }
            }
            let mut bad_tile = rows[n - 1].clone();
            if let Json::Obj(row) = &mut bad_tile {
                if let Json::Obj(spec) = row.get_mut("spec").unwrap() {
                    spec.insert("h".into(), Json::num(999.0));
                    spec.insert("w".into(), Json::num(999.0));
                }
                row.insert(
                    "tile".into(),
                    Json::obj(vec![
                        ("mr", Json::num(3.0)),
                        ("nr", Json::num(7.0)),
                        ("p50_us", Json::num(1.0)),
                    ]),
                );
            }
            rows.push(bad_tile);
        }
        let cache = TuneCache::from_json(&doc);
        assert_eq!(cache.degraded(), 2, "two malformed entries skipped");
        assert!(!cache.is_empty(), "well-formed entries must survive");
        // The poisoned spec's ranking is gone -> miss, re-tune.
        assert!(cache.lookup_algos(&ConvSpec::paper(7, 1, 1, 32, 832)).is_none());
    }

    #[test]
    fn measurement_counter_accumulates() {
        let before = measurement_count();
        note_measurements(3);
        assert_eq!(measurement_count() - before, 3);
    }
}
