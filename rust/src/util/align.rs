//! [`AlignedF32Buf`]: a growable f32 buffer whose exposed slice always
//! starts on a 64-byte (cache-line) boundary.
//!
//! Rust's global allocator only guarantees 4-byte alignment for
//! `Vec<f32>`, so a vectorized kernel reading a `Vec`-backed buffer can
//! start mid-cache-line and every 8-wide load straddles two lines. This
//! buffer over-allocates by one cache line and exposes the first aligned
//! window, in safe code (no `unsafe` allocator calls): the
//! [`Workspace`](crate::backend::Workspace) scratch regions and the
//! plan-owned [`PackedFilters`](crate::cpuref::pack::PackedFilters)
//! panels both sit on it, so their 64-byte-aligned internal offsets
//! translate to 64-byte-aligned addresses.

/// Alignment guarantee of [`AlignedF32Buf::as_slice`], in bytes.
pub const ALIGN_BYTES: usize = 64;

const F32_BYTES: usize = std::mem::size_of::<f32>();

/// Worst-case f32s between the raw allocation start and the first
/// 64-byte boundary.
const PAD_ELEMS: usize = ALIGN_BYTES / F32_BYTES;

/// A growable f32 buffer aligned to [`ALIGN_BYTES`]. Grows, never
/// shrinks; growing zero-fills new elements and preserves the prefix
/// contents (the backing allocation may move, in which case the aligned
/// window is recomputed).
/// Deliberately **not** `Clone`: a derived clone would element-copy the
/// raw Vec into a differently-aligned allocation and expose a shifted
/// window. Nothing needs cloning today (the packed-weight and workspace
/// owners share via `Arc` / own per-replica buffers); implement a
/// window-copying clone if that changes.
#[derive(Debug, Default)]
pub struct AlignedF32Buf {
    raw: Vec<f32>,
    len: usize,
}

impl AlignedF32Buf {
    pub fn new() -> AlignedF32Buf {
        AlignedF32Buf::default()
    }

    /// A zero-filled aligned buffer of exactly `elems` f32s.
    pub fn zeroed(elems: usize) -> AlignedF32Buf {
        let mut b = AlignedF32Buf::new();
        b.ensure_len(elems);
        b
    }

    /// Logical length in f32s (the exposed slice's length).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Grow the logical length to at least `elems` (no-op when already
    /// large enough). New elements are zero; existing contents survive.
    pub fn ensure_len(&mut self, elems: usize) {
        if elems <= self.len {
            return;
        }
        // Preserve the aligned window's contents across a possible
        // realloc-induced shift of the alignment offset: materialize the
        // old window first, then rebuild.
        let old: Vec<f32> = self.as_slice().to_vec();
        self.raw.clear();
        self.raw.resize(elems + PAD_ELEMS, 0.0);
        self.len = elems;
        self.as_mut_slice()[..old.len()].copy_from_slice(&old);
    }

    /// f32s between the raw allocation start and the first 64-byte
    /// boundary (recomputed per call: the Vec may have moved).
    fn start(&self) -> usize {
        let addr = self.raw.as_ptr() as usize;
        // Vec<f32> is at least 4-aligned, so the byte distance to the
        // next 64-byte boundary is an exact number of f32s.
        (ALIGN_BYTES - addr % ALIGN_BYTES) % ALIGN_BYTES / F32_BYTES
    }

    /// The aligned window: `len` f32s starting on a 64-byte boundary.
    pub fn as_slice(&self) -> &[f32] {
        if self.len == 0 {
            return &[];
        }
        let s = self.start();
        &self.raw[s..s + self.len]
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        if self.len == 0 {
            return &mut [];
        }
        let s = self.start();
        &mut self.raw[s..s + self.len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_is_64_byte_aligned() {
        for elems in [1usize, 3, 16, 1000, 4097] {
            let b = AlignedF32Buf::zeroed(elems);
            assert_eq!(b.len(), elems);
            assert_eq!(b.as_slice().len(), elems);
            assert_eq!(b.as_slice().as_ptr() as usize % ALIGN_BYTES, 0, "{elems} elems");
        }
    }

    #[test]
    fn empty_buffer_is_safe() {
        let mut b = AlignedF32Buf::new();
        assert!(b.is_empty());
        assert!(b.as_slice().is_empty());
        assert!(b.as_mut_slice().is_empty());
    }

    #[test]
    fn grow_preserves_contents_and_alignment() {
        let mut b = AlignedF32Buf::zeroed(4);
        b.as_mut_slice().copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        b.ensure_len(100);
        assert_eq!(b.len(), 100);
        assert_eq!(&b.as_slice()[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert!(b.as_slice()[4..].iter().all(|&v| v == 0.0));
        assert_eq!(b.as_slice().as_ptr() as usize % ALIGN_BYTES, 0);
        // Shrinking requests are no-ops.
        b.ensure_len(10);
        assert_eq!(b.len(), 100);
        assert_eq!(&b.as_slice()[..4], &[1.0, 2.0, 3.0, 4.0]);
    }
}
