//! Minimal JSON value model, writer and parser.
//!
//! `serde`/`serde_json` are not in the offline vendor set. This module
//! implements the subset of JSON the project needs — the AOT artifact
//! manifest written by `python/compile/aot.py` and the result dumps the
//! bench harness emits — with a strict recursive-descent parser and a
//! deterministic (sorted-key) writer.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// Access a field of an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 9.0e15 {
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{n}"));
        }
    } else {
        // JSON has no Inf/NaN; null is the conventional fallback.
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document. The entire input must be consumed (modulo
/// trailing whitespace).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.unicode_escape()?;
                            s.push(cp);
                            continue; // unicode_escape advanced pos itself
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let txt = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = txt.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, ParseError> {
        // self.pos is at 'u'
        self.pos += 1;
        let hex = self
            .bytes
            .get(self.pos..self.pos + 4)
            .ok_or_else(|| self.err("short \\u escape"))?;
        let code = u32::from_str_radix(
            std::str::from_utf8(hex).map_err(|_| self.err("bad \\u escape"))?,
            16,
        )
        .map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        // Surrogate pairs: only handle BMP + paired surrogates.
        if (0xD800..0xDC00).contains(&code) {
            if self.bytes.get(self.pos) == Some(&b'\\')
                && self.bytes.get(self.pos + 1) == Some(&b'u')
            {
                self.pos += 2;
                let hex2 = self
                    .bytes
                    .get(self.pos..self.pos + 4)
                    .ok_or_else(|| self.err("short low surrogate"))?;
                let low = u32::from_str_radix(
                    std::str::from_utf8(hex2).map_err(|_| self.err("bad surrogate"))?,
                    16,
                )
                .map_err(|_| self.err("bad surrogate"))?;
                self.pos += 4;
                let c = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                return char::from_u32(c).ok_or_else(|| self.err("bad surrogate pair"));
            }
            return Err(self.err("lone high surrogate"));
        }
        char::from_u32(code).ok_or_else(|| self.err("bad code point"))
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj(vec![
            ("name", Json::str("cuconv")),
            ("n", Json::num(256)),
            ("ok", Json::Bool(true)),
            ("nothing", Json::Null),
            ("xs", Json::arr(vec![Json::num(1), Json::num(2.5), Json::num(-3)])),
        ]);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": {"b": [1, {"c": "d"}]}, "e": []}"#).unwrap();
        let inner = v.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(inner[0].as_f64(), Some(1.0));
        assert_eq!(inner[1].get("c").unwrap().as_str(), Some("d"));
        assert!(v.get("e").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::str("line1\nline2\t\"quoted\" \\ end");
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::str("é"));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::str("😀"));
    }

    #[test]
    fn numbers_parse() {
        assert_eq!(parse("-12.5e2").unwrap().as_f64(), Some(-1250.0));
        assert_eq!(parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(parse("3.5").unwrap().as_usize(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::num(5).to_string_compact(), "5");
        assert_eq!(Json::num(5.25).to_string_compact(), "5.25");
    }

    #[test]
    fn obj_keys_sorted_deterministically() {
        let v = Json::obj(vec![("z", Json::num(1)), ("a", Json::num(2))]);
        assert_eq!(v.to_string_compact(), r#"{"a":2,"z":1}"#);
    }
}
