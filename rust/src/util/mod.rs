//! Small self-contained utilities.
//!
//! The build environment is fully offline and the vendored crate set does
//! not include `serde`, `rand`, `proptest` or `criterion`, so this module
//! provides the minimal equivalents the rest of the crate needs:
//!
//! * [`align`] — a growable 64-byte-aligned f32 buffer (workspace and
//!   packed-weight backing storage).
//! * [`json`] — a tiny JSON value model, writer and recursive-descent
//!   parser (used for `artifacts/manifest.json` and result dumps).
//! * [`rng`] — a splitmix64/xoshiro256** PRNG with normal/uniform helpers.
//! * [`stats`] — summary statistics and fixed-bound latency histograms.
//! * [`timer`] — monotonic wall-clock timing helpers for the bench harness.
//! * [`prop`] — a miniature property-based testing framework with
//!   shrinking, in the spirit of `proptest`.

pub mod align;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
