//! Miniature property-based testing framework.
//!
//! `proptest` is not available in the offline vendor set, so this module
//! provides the core of it: generators driven by a seeded [`Rng`], a
//! configurable number of cases, and greedy shrinking of failing inputs
//! toward minimal counterexamples. Property tests across the crate (conv
//! geometry, batcher invariants, JSON round-trips, gpumodel monotonicity)
//! are built on this.

use crate::util::rng::Rng;

/// A generator of values plus a shrinking strategy.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;

    /// Generate a value from the PRNG.
    fn gen(&self, rng: &mut Rng) -> Self::Value;

    /// Candidate smaller values to try when shrinking a failure.
    /// Default: no shrinking.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform usize in `[lo, hi]`, shrinking toward `lo`.
pub struct UsizeIn {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeIn {
    type Value = usize;

    fn gen(&self, rng: &mut Rng) -> usize {
        rng.range(self.lo, self.hi)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            let mid = self.lo + (*v - self.lo) / 2;
            if mid != self.lo && mid != *v {
                out.push(mid);
            }
            if *v - 1 != self.lo {
                out.push(*v - 1);
            }
        }
        out
    }
}

/// Uniform f32 in `[lo, hi)`, shrinking toward 0 (clamped into range).
pub struct F32In {
    pub lo: f32,
    pub hi: f32,
}

impl Gen for F32In {
    type Value = f32;

    fn gen(&self, rng: &mut Rng) -> f32 {
        rng.uniform_f32(self.lo, self.hi)
    }

    fn shrink(&self, v: &f32) -> Vec<f32> {
        let zero = 0f32.clamp(self.lo, self.hi);
        if *v != zero {
            vec![zero, *v / 2.0]
        } else {
            Vec::new()
        }
    }
}

/// One-of: pick uniformly among fixed choices. No shrinking (choices are
/// unordered).
pub struct OneOf<T: Clone + std::fmt::Debug>(pub Vec<T>);

impl<T: Clone + std::fmt::Debug> Gen for OneOf<T> {
    type Value = T;

    fn gen(&self, rng: &mut Rng) -> T {
        rng.choose(&self.0).clone()
    }
}

/// Vec of values from an element generator with length in `[min_len, max_len]`.
/// Shrinks by halving length, dropping one element, and shrinking elements.
pub struct VecOf<G: Gen> {
    pub elem: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;

    fn gen(&self, rng: &mut Rng) -> Vec<G::Value> {
        let len = rng.range(self.min_len, self.max_len);
        (0..len).map(|_| self.elem.gen(rng)).collect()
    }

    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // Halve.
            let half = v.len().max(2 * self.min_len) / 2;
            out.push(v[..half.max(self.min_len)].to_vec());
            // Drop last.
            out.push(v[..v.len() - 1].to_vec());
        }
        // Shrink the first shrinkable element.
        for (i, e) in v.iter().enumerate() {
            let cands = self.elem.shrink(e);
            if let Some(smaller) = cands.first() {
                let mut copy = v.clone();
                copy[i] = smaller.clone();
                out.push(copy);
                break;
            }
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairOf<A: Gen, B: Gen>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairOf<A, B> {
    type Value = (A::Value, B::Value);

    fn gen(&self, rng: &mut Rng) -> Self::Value {
        (self.0.gen(rng), self.1.gen(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xC0FFEE, max_shrink_steps: 500 }
    }
}

/// Result of a failed property: the original and the shrunk counterexample.
#[derive(Debug)]
pub struct Failure<V> {
    pub original: V,
    pub shrunk: V,
    pub message: String,
}

/// Check `prop` on `config.cases` generated values. Returns `Ok(())` or the
/// shrunk counterexample. `prop` returns `Err(reason)` or panics to fail.
pub fn check<G: Gen>(
    config: Config,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) -> Result<(), Failure<G::Value>> {
    let mut rng = Rng::new(config.seed);
    for case in 0..config.cases {
        let value = gen.gen(&mut rng);
        if let Err(msg) = run_case(&prop, &value) {
            // Shrink.
            let mut best = value.clone();
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < config.max_shrink_steps {
                for cand in gen.shrink(&best) {
                    steps += 1;
                    if let Err(m) = run_case(&prop, &cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if steps >= config.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            return Err(Failure {
                original: value,
                shrunk: best,
                message: format!("case {case}: {best_msg}"),
            });
        }
    }
    Ok(())
}

fn run_case<V>(prop: &impl Fn(&V) -> Result<(), String>, v: &V) -> Result<(), String> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(v))) {
        Ok(r) => r,
        Err(p) => {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "panic".to_string());
            Err(format!("panicked: {msg}"))
        }
    }
}

/// Assert a property holds; panics with the shrunk counterexample on failure.
pub fn assert_prop<G: Gen>(
    config: Config,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    if let Err(f) = check(config, gen, prop) {
        panic!(
            "property failed: {}\n  original: {:?}\n  shrunk:   {:?}",
            f.message, f.original, f.shrunk
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        assert_prop(Config::default(), &UsizeIn { lo: 0, hi: 100 }, |&v| {
            if v <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let gen = UsizeIn { lo: 0, hi: 1000 };
        let res = check(Config::default(), &gen, |&v| {
            if v < 500 {
                Ok(())
            } else {
                Err(format!("{v} >= 500"))
            }
        });
        let f = res.expect_err("must fail");
        assert_eq!(f.shrunk, 500, "greedy shrink should reach the boundary");
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        let gen = VecOf { elem: UsizeIn { lo: 0, hi: 9 }, min_len: 0, max_len: 50 };
        let res = check(Config::default(), &gen, |v| {
            if v.len() < 3 {
                Ok(())
            } else {
                Err("too long".into())
            }
        });
        let f = res.expect_err("must fail");
        assert_eq!(f.shrunk.len(), 3);
    }

    #[test]
    fn panics_are_caught_as_failures() {
        let gen = UsizeIn { lo: 0, hi: 10 };
        let res = check(Config { cases: 64, ..Config::default() }, &gen, |&v| {
            assert!(v < 11, "generator out of bounds");
            if v == 7 {
                panic!("boom on 7");
            }
            Ok(())
        });
        let f = res.expect_err("must fail");
        assert!(f.message.contains("boom"), "{}", f.message);
    }

    #[test]
    fn pair_generator_shrinks_both_sides() {
        let gen = PairOf(UsizeIn { lo: 0, hi: 100 }, UsizeIn { lo: 0, hi: 100 });
        let res = check(Config::default(), &gen, |&(a, b)| {
            if a + b < 50 {
                Ok(())
            } else {
                Err("sum too big".into())
            }
        });
        let f = res.expect_err("must fail");
        assert!(f.shrunk.0 + f.shrunk.1 >= 50);
        // Shrunk sum should be no larger than original sum.
        assert!(f.shrunk.0 + f.shrunk.1 <= f.original.0 + f.original.1);
    }
}
