//! Deterministic PRNG (xoshiro256** seeded via splitmix64).
//!
//! `rand` is not available in the offline vendor set; this is the standard
//! public-domain xoshiro256** generator, which is more than adequate for
//! test-data generation, synthetic workloads and the property-test driver.

/// splitmix64 step — used to expand a single `u64` seed into the four
/// xoshiro words and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** deterministic pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine for
        // test workloads; use widening multiply to avoid modulo bias.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Standard normal sample (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform_f32(lo, hi);
        }
    }

    /// Pick a random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f32_uniform_respects_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.uniform_f32(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(13);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2_000 {
            let v = r.range(3, 6);
            assert!((3..=6).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 6;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_mean_and_var_roughly_standard() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }
}
