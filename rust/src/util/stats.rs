//! Summary statistics and latency histograms for the bench harness and
//! the coordinator's metrics.

/// Summary of a sample of observations (times in seconds, speedups, ...).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            max: sorted[n - 1],
        })
    }
}

/// Linear-interpolated percentile of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean; all inputs must be positive.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Log-bucketed latency histogram, suitable for lock-free-ish metric
/// aggregation in the coordinator (buckets grow ×2 from `base`).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Lower bound of the first bucket, in seconds.
    base: f64,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    max: f64,
}

impl LatencyHistogram {
    /// `base` is the upper bound of bucket 0 in seconds; each subsequent
    /// bucket doubles. 40 buckets starting at 1 µs spans >1000 s.
    pub fn new(base: f64, buckets: usize) -> Self {
        LatencyHistogram {
            base,
            counts: vec![0; buckets],
            total: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    /// Default histogram: 1 µs base, 40 doubling buckets.
    pub fn standard() -> Self {
        Self::new(1e-6, 40)
    }

    pub fn record(&mut self, seconds: f64) {
        let idx = if seconds <= self.base {
            0
        } else {
            ((seconds / self.base).log2().ceil() as usize).min(self.counts.len() - 1)
        };
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += seconds;
        if seconds > self.max {
            self.max = seconds;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Upper bound of the bucket containing the q-quantile (conservative
    /// estimate; exact values are not retained). An empty histogram
    /// reports 0; otherwise the answer is always the bound of a
    /// *populated* bucket — `q == 0.0` targets the first sample rather
    /// than a count of zero (which would select bucket 0 even when
    /// nothing was ever recorded there).
    pub fn quantile_upper_bound(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base * 2f64.powi(i as i32);
            }
        }
        self.base * 2f64.powi(self.counts.len() as i32 - 1)
    }

    /// Samples known to be at or below `seconds`: the summed counts of
    /// every bucket whose upper bound is ≤ `seconds`. Conservative in
    /// the same direction as [`quantile_upper_bound`] — a sample in a
    /// bucket straddling the threshold is *not* counted, so an SLO
    /// attainment computed from this can only under-report, never
    /// flatter.
    ///
    /// [`quantile_upper_bound`]: LatencyHistogram::quantile_upper_bound
    pub fn count_at_or_below(&self, seconds: f64) -> u64 {
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if self.base * 2f64.powi(i as i32) <= seconds {
                acc += c;
            } else {
                break;
            }
        }
        acc
    }

    /// Merge another histogram with identical geometry.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(self.base, other.base);
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

/// Format seconds with an adaptive unit (µs / ms / s).
pub fn fmt_seconds(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_and_quantiles() {
        let mut h = LatencyHistogram::standard();
        for _ in 0..99 {
            h.record(10e-6); // ~10µs
        }
        h.record(500e-3); // one 500ms outlier
        assert_eq!(h.count(), 100);
        // p50 bucket bound should be near 16µs (2^4 µs), way below the outlier.
        let p50 = h.quantile_upper_bound(0.50);
        assert!(p50 < 100e-6, "p50 bound {p50}");
        let p999 = h.quantile_upper_bound(0.999);
        assert!(p999 > 100e-3, "p99.9 bound {p999}");
        assert!((h.max() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_of_empty_histogram_is_zero() {
        let h = LatencyHistogram::standard();
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile_upper_bound(q), 0.0, "q={q}");
        }
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn quantile_of_single_sample_bounds_it_at_every_q() {
        let mut h = LatencyHistogram::standard();
        let sample = 3e-3;
        h.record(sample);
        for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
            let bound = h.quantile_upper_bound(q);
            assert!(bound >= sample, "q={q}: bound {bound} below the only sample");
            // The bound is the sample's bucket ceiling, not a farther
            // bucket: one doubling away at most.
            assert!(bound < 2.0 * sample, "q={q}: bound {bound} overshoots");
        }
    }

    #[test]
    fn quantiles_of_all_equal_samples_agree() {
        let mut h = LatencyHistogram::standard();
        for _ in 0..1000 {
            h.record(250e-6);
        }
        let p50 = h.quantile_upper_bound(0.50);
        let p99 = h.quantile_upper_bound(0.99);
        let p100 = h.quantile_upper_bound(1.0);
        assert_eq!(p50, p99, "identical samples must share one bucket bound");
        assert_eq!(p99, p100);
        assert!(p50 >= 250e-6 && p50 < 500e-6, "bound {p50}");
    }

    #[test]
    fn quantile_clamps_out_of_range_q() {
        let mut h = LatencyHistogram::standard();
        h.record(1e-3);
        assert_eq!(h.quantile_upper_bound(-1.0), h.quantile_upper_bound(0.0));
        assert_eq!(h.quantile_upper_bound(2.0), h.quantile_upper_bound(1.0));
    }

    #[test]
    fn quantile_below_base_and_saturated_bucket_edges() {
        // Sub-base samples land in bucket 0 (bound = base); samples
        // beyond the last bucket saturate into it rather than vanish.
        let mut h = LatencyHistogram::new(1e-6, 4); // covers up to 8µs
        h.record(1e-9);
        assert_eq!(h.quantile_upper_bound(1.0), 1e-6);
        h.record(5.0); // way past the last bucket
        let top = h.quantile_upper_bound(1.0);
        assert_eq!(top, 1e-6 * 8.0, "overflow sample must sit in the last bucket");
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn count_at_or_below_is_conservative() {
        let mut h = LatencyHistogram::standard();
        for _ in 0..10 {
            h.record(10e-6); // bucket bound 16µs
        }
        h.record(300e-3); // far bucket
        // Everything at 10µs is surely within 16µs and above.
        assert_eq!(h.count_at_or_below(16e-6), 10);
        assert_eq!(h.count_at_or_below(1.0), 11);
        // A threshold below the samples' bucket bound counts nothing —
        // under-reporting, never flattering.
        assert_eq!(h.count_at_or_below(8e-6), 0);
        assert_eq!(LatencyHistogram::standard().count_at_or_below(1.0), 0);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::standard();
        let mut b = LatencyHistogram::standard();
        a.record(1e-3);
        b.record(2e-3);
        b.record(4e-3);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!(a.mean() > 0.0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_seconds(5e-6).ends_with("µs"));
        assert!(fmt_seconds(5e-3).ends_with("ms"));
        assert!(fmt_seconds(5.0).ends_with('s'));
    }
}
