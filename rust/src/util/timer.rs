//! Wall-clock timing helpers for the bench harness.
//!
//! `criterion` is unavailable offline; [`bench_fn`] provides the small
//! slice of it we need: warmup, fixed-iteration measurement, and a
//! [`Summary`](crate::util::stats::Summary) of per-iteration times.

use std::time::Instant;

use crate::util::stats::Summary;

/// Time a single invocation of `f`, returning (seconds, result).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let start = Instant::now();
    let out = f();
    (start.elapsed().as_secs_f64(), out)
}

/// Options for [`bench_fn`].
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup_iters: 3, iters: 30 }
    }
}

impl BenchOpts {
    /// A faster profile for expensive end-to-end benches.
    pub fn quick() -> Self {
        BenchOpts { warmup_iters: 1, iters: 10 }
    }
}

/// Run `f` `opts.warmup_iters` times unmeasured then `opts.iters` times
/// measured; return the per-iteration timing summary.
pub fn bench_fn(opts: BenchOpts, mut f: impl FnMut()) -> Summary {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(opts.iters);
    for _ in 0..opts.iters {
        let start = Instant::now();
        f();
        samples.push(start.elapsed().as_secs_f64());
    }
    Summary::of(&samples).expect("iters > 0")
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept behind one name so call sites read uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_returns_result() {
        let (secs, v) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn bench_fn_runs_expected_iterations() {
        let mut count = 0usize;
        let opts = BenchOpts { warmup_iters: 2, iters: 5 };
        let s = bench_fn(opts, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
        assert!(s.min <= s.p50 && s.p50 <= s.max);
    }
}
