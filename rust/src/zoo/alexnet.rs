//! AlexNet distinct stride-1 convolution configurations.
//!
//! Single-tower (ungrouped) AlexNet: conv1 (11×11 stride 4) is excluded
//! as non-stride-1; conv2 (5×5 on 27×27×96) and conv3–conv5 (3×3 on
//! 13×13) remain. Reproduces Table 1's 4 configs = 75% 3×3 + 25% 5×5.

use super::{Network, ZooEntry};
use crate::conv::ConvSpec;

fn e(layer: &'static str, hw: usize, k: usize, m: usize, c: usize) -> ZooEntry {
    ZooEntry {
        network: Network::AlexNet,
        layer,
        spec: ConvSpec::paper(hw, 1, k, m, c),
    }
}

pub fn configs() -> Vec<ZooEntry> {
    vec![
        e("conv2", 27, 5, 256, 96),
        e("conv3", 13, 3, 384, 256),
        e("conv4", 13, 3, 384, 384),
        e("conv5", 13, 3, 256, 384),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::FilterSize;

    #[test]
    fn counts_match_table1_row() {
        let cfgs = configs();
        assert_eq!(cfgs.len(), 4);
        let n3 = cfgs.iter().filter(|e| e.spec.filter_size() == FilterSize::F3x3).count();
        let n5 = cfgs.iter().filter(|e| e.spec.filter_size() == FilterSize::F5x5).count();
        assert_eq!((n3, n5), (3, 1));
    }

    #[test]
    fn last_conv_input_is_13x13x384() {
        let conv5 = configs().into_iter().find(|e| e.layer == "conv5").unwrap();
        assert_eq!((conv5.spec.h, conv5.spec.c), (13, 384));
    }
}
