//! GoogleNet (Inception v1) distinct stride-1 convolution configurations.
//!
//! Derived from Szegedy et al., "Going deeper with convolutions", Table 1:
//! conv2's 1×1 reduce and 3×3, plus each inception module's 1×1, 3×3
//! reduce, 3×3, 5×5 reduce and 5×5 branches. Pool projections and the two
//! auxiliary classifiers are excluded (see `zoo` module docs) — this is
//! the only counting that reproduces the paper's 42 = 24 + 10 + 8 census.
//! Duplicate (H, K, M, C) tuples across modules are listed once.

use super::{Network, ZooEntry};
use crate::conv::ConvSpec;

fn e(layer: &'static str, hw: usize, k: usize, m: usize, c: usize) -> ZooEntry {
    ZooEntry {
        network: Network::GoogleNet,
        layer,
        spec: ConvSpec::paper(hw, 1, k, m, c),
    }
}

pub fn configs() -> Vec<ZooEntry> {
    vec![
        // ---- stem ----
        e("conv2.reduce", 56, 1, 64, 64),
        e("conv2.3x3", 56, 3, 192, 64),
        // ---- inception 3a (28x28, depth 192) ----
        e("inception3a.1x1", 28, 1, 64, 192),
        e("inception3a.3x3reduce", 28, 1, 96, 192),
        e("inception3a.5x5reduce", 28, 1, 16, 192),
        e("inception3a.3x3", 28, 3, 128, 96),
        e("inception3a.5x5", 28, 5, 32, 16),
        // ---- inception 3b (28x28, depth 256) ----
        // 1x1 and 3x3reduce are both 128 filters -> one distinct config.
        e("inception3b.1x1", 28, 1, 128, 256),
        e("inception3b.5x5reduce", 28, 1, 32, 256),
        e("inception3b.3x3", 28, 3, 192, 128),
        e("inception3b.5x5", 28, 5, 96, 32),
        // ---- inception 4a (14x14, depth 480) ----
        e("inception4a.1x1", 14, 1, 192, 480),
        e("inception4a.3x3reduce", 14, 1, 96, 480),
        e("inception4a.5x5reduce", 14, 1, 16, 480),
        e("inception4a.3x3", 14, 3, 208, 96),
        e("inception4a.5x5", 14, 5, 48, 16),
        // ---- inception 4b (14x14, depth 512) ----
        e("inception4b.1x1", 14, 1, 160, 512),
        e("inception4b.3x3reduce", 14, 1, 112, 512),
        e("inception4b.5x5reduce", 14, 1, 24, 512),
        e("inception4b.3x3", 14, 3, 224, 112),
        e("inception4b.5x5", 14, 5, 64, 24),
        // ---- inception 4c (14x14, depth 512) ----
        // 5x5reduce (24) duplicates 4b's; 5x5 (64 on 24) duplicates 4b's.
        e("inception4c.1x1", 14, 1, 128, 512),
        e("inception4c.3x3", 14, 3, 256, 128),
        // ---- inception 4d (14x14, depth 528) ----
        e("inception4d.1x1", 14, 1, 112, 528),
        e("inception4d.3x3reduce", 14, 1, 144, 528),
        e("inception4d.5x5reduce", 14, 1, 32, 528),
        e("inception4d.3x3", 14, 3, 288, 144),
        e("inception4d.5x5", 14, 5, 64, 32),
        // ---- inception 4e (14x14, depth 528) ----
        // 5x5reduce (32) duplicates 4d's.
        e("inception4e.1x1", 14, 1, 256, 528),
        e("inception4e.3x3reduce", 14, 1, 160, 528),
        e("inception4e.3x3", 14, 3, 320, 160),
        e("inception4e.5x5", 14, 5, 128, 32),
        // ---- inception 5a (7x7, depth 832) ----
        e("inception5a.1x1", 7, 1, 256, 832),
        e("inception5a.3x3reduce", 7, 1, 160, 832),
        // The paper's maximum-speedup configuration (2.29x at batch 1):
        e("inception5a.5x5reduce", 7, 1, 32, 832),
        e("inception5a.3x3", 7, 3, 320, 160),
        e("inception5a.5x5", 7, 5, 128, 32),
        // ---- inception 5b (7x7, depth 832) ----
        e("inception5b.1x1", 7, 1, 384, 832),
        e("inception5b.3x3reduce", 7, 1, 192, 832),
        e("inception5b.5x5reduce", 7, 1, 48, 832),
        e("inception5b.3x3", 7, 3, 384, 192),
        e("inception5b.5x5", 7, 5, 128, 48),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::FilterSize;

    #[test]
    fn counts_match_table1_row() {
        let cfgs = configs();
        assert_eq!(cfgs.len(), 42);
        let count = |f: FilterSize| cfgs.iter().filter(|e| e.spec.filter_size() == f).count();
        assert_eq!(count(FilterSize::F1x1), 24);
        assert_eq!(count(FilterSize::F3x3), 10);
        assert_eq!(count(FilterSize::F5x5), 8);
    }

    #[test]
    fn last_conv_depth_is_832() {
        // Table 1: input size to last convolutional layer is 7x7x832.
        let max_depth_at_7 = configs()
            .iter()
            .filter(|e| e.spec.h == 7)
            .map(|e| e.spec.c)
            .max()
            .unwrap();
        assert_eq!(max_depth_at_7, 832);
    }
}
