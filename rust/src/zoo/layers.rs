//! Full forward conv-layer *sequences* of the five networks, with
//! repetition.
//!
//! The census in the sibling modules lists the *distinct* stride-1
//! configurations (Table 1); network-level conclusions ("convolutions
//! account for a large part of the overall network execution time", §1)
//! need the actual execution sequence, where VGG19 runs 16 convs and
//! ResNet-50 repeats each bottleneck shape per block. This module
//! expands the distinct configs into full sequences, used by
//! [`crate::coordinator::plan`]-style accounting and the ablation
//! benches.

use super::{network_configs, Network, ZooEntry};

/// One step of a network's conv execution: a distinct config times its
/// repetition count (stride-1 convs only, matching the census scope).
#[derive(Debug, Clone)]
pub struct LayerStep {
    pub entry: ZooEntry,
    /// How many times this exact configuration runs in one forward pass.
    pub count: usize,
}

/// Repetition count of a distinct config within one forward pass.
fn repetition(net: Network, layer: &str) -> usize {
    match net {
        // VGG19 stages repeat their second shape: conv3_2 == conv3_3 ==
        // conv3_4, conv4_2..conv4_4, conv5_1..conv5_4 share one shape.
        Network::Vgg19 => match layer {
            "conv3_2" | "conv4_2" => 3,
            "conv5_1" => 4,
            _ => 1,
        },
        // ResNet-50 bottleneck shapes repeat per block in each stage
        // (conv2: 3 blocks, conv3: 4, conv4: 6, conv5: 3). First-block
        // reduces run at stride 2 for conv3-5 and are outside the
        // stride-1 census; the remaining blocks share these shapes.
        Network::ResNet50 => {
            let blocks = if layer.starts_with("conv2") {
                3
            } else if layer.starts_with("conv3") {
                4
            } else if layer.starts_with("conv4") {
                6
            } else {
                3
            };
            if layer.ends_with("reduce1x1") {
                blocks - 1 // first block's reduce is the stride-2 one
            } else {
                blocks
            }
        }
        // SqueezeNet: fire2/fire3 share expand shapes; fire6/fire7
        // share expand shapes (annotated in the config list).
        Network::SqueezeNet => match layer {
            "fire2.expand1x1" | "fire2.expand3x3" => 2,
            "fire6.expand1x1" | "fire6.expand3x3" => 2,
            _ => 1,
        },
        // GoogleNet: 4b/4c share the 5x5 branch shapes; 4d/4e share the
        // 5x5-reduce; 5a/5b share the pool-proj (excluded) — within the
        // census only these two dedups repeat.
        Network::GoogleNet => match layer {
            "inception4b.5x5reduce" | "inception4b.5x5" => 2,
            "inception4d.5x5reduce" => 2,
            // 3b and 4c use the same filter count for their 1x1 and
            // 3x3-reduce branches, so one distinct config runs twice.
            "inception3b.1x1" | "inception4c.1x1" => 2,
            _ => 1,
        },
        Network::AlexNet => 1,
    }
}

/// The full stride-1 conv sequence of one forward pass.
pub fn network_layers(net: Network) -> Vec<LayerStep> {
    network_configs(net)
        .into_iter()
        .map(|entry| LayerStep { count: repetition(net, entry.layer), entry })
        .collect()
}

/// Total stride-1 convolutions executed in one forward pass.
pub fn conv_executions(net: Network) -> usize {
    network_layers(net).iter().map(|l| l.count).sum()
}

/// Total forward MACs of the stride-1 convs at a batch size.
pub fn network_macs(net: Network, batch: usize) -> u64 {
    network_layers(net)
        .iter()
        .map(|l| l.entry.spec.with_batch(batch).macs() * l.count as u64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg19_runs_sixteen_convs() {
        // VGG19's defining property: 16 conv layers, all 3x3 stride 1.
        assert_eq!(conv_executions(Network::Vgg19), 16);
    }

    #[test]
    fn resnet50_bottleneck_expansion() {
        // 3+4+6+3 = 16 bottlenecks; each contributes a stride-1 3x3
        // (first-stage blocks included: downsampling is on the first
        // conv of the stage in this derivation) and expand 1x1s.
        let layers = network_layers(Network::ResNet50);
        let threes: usize = layers
            .iter()
            .filter(|l| l.entry.spec.kh == 3)
            .map(|l| l.count)
            .sum();
        assert_eq!(threes, 16);
        let total = conv_executions(Network::ResNet50);
        // 16 blocks x 3 convs minus the four stride-2 first-block
        // reduces that fall outside the stride-1 census.
        assert_eq!(total, 16 * 3 - 4);
    }

    #[test]
    fn squeezenet_fire_modules() {
        // fire2..fire9 = 8 squeezes + 8 expand pairs + conv10 = 25.
        assert_eq!(conv_executions(Network::SqueezeNet), 25);
    }

    #[test]
    fn googlenet_census_expansion_is_consistent() {
        let layers = network_layers(Network::GoogleNet);
        let total = conv_executions(Network::GoogleNet);
        // 2 stem + 9 inceptions x 5 counted branches = 47 executions
        // (pool projections and aux classifiers excluded, as in the
        // census; shared shapes counted once per occurrence).
        assert_eq!(layers.len(), 42);
        assert_eq!(total, 47);
    }

    #[test]
    fn macs_scale_with_batch() {
        for net in Network::ALL {
            let m1 = network_macs(net, 1);
            let m8 = network_macs(net, 8);
            assert_eq!(m8, 8 * m1, "{net:?}");
            assert!(m1 > 0);
        }
    }

    #[test]
    fn vgg_dominates_compute() {
        // §1 motivation sanity: VGG19's conv MACs dwarf SqueezeNet's.
        assert!(network_macs(Network::Vgg19, 1) > 10 * network_macs(Network::SqueezeNet, 1));
    }
}
