//! The paper's evaluation workload: every *distinct* stride-1 forward
//! convolution configuration of the five CNNs in Table 1.
//!
//! Counts match Table 1 exactly:
//!
//! | network    | configs | 1×1 | 3×3 | 5×5 |
//! |------------|---------|-----|-----|-----|
//! | GoogleNet  | 42      | 24  | 10  | 8   |
//! | SqueezeNet | 21      | 15  | 6   | 0   |
//! | AlexNet    | 4       | 0   | 3   | 1   |
//! | ResNet-50  | 12      | 8   | 4   | 0   |
//! | VGG19      | 9       | 0   | 9   | 0   |
//!
//! 88 distinct configs × 7 batch sizes = 616 cases (the paper's ">600").
//!
//! The census lists *distinct stride-1* configurations only;
//! [`crate::net`] expands these sequences into runnable input-to-logits
//! forward graphs (stride≠1 stems, pooling, branches and classifier
//! tails restored), cross-checked against this census by test.
//!
//! Derivation notes (the paper lists only the census, not the configs):
//! * GoogleNet: conv2 3×3-reduce plus, per inception module, the 1×1,
//!   3×3-reduce, 3×3, 5×5-reduce and 5×5 branches. Pool-projection 1×1s
//!   and the auxiliary classifiers are excluded — this is the only
//!   counting that reproduces 24/10/8 exactly.
//! * SqueezeNet: v1.0 squeeze/expand convs of fire2–fire9 plus conv10;
//!   reproduces 15/6 exactly.
//! * AlexNet: single-tower (ungrouped) conv2–conv5; conv1 (11×11 stride
//!   4) is excluded as non-stride-1; reproduces 3×3 75% / 5×5 25%.
//! * ResNet-50: bottleneck convs with downsampling on the first conv of
//!   each stage (stride 2, excluded). The conv2_1 64→64 reduce is folded
//!   into the census to land on the published 8×1×1 + 4×3×3 = 12.
//! * VGG19: all 16 convs are 3×3 stride 1; 9 distinct shapes.

pub mod layers;

mod alexnet;
mod googlenet;
mod resnet50;
mod squeezenet;
mod vgg19;

use crate::conv::{ConvSpec, FilterSize};

/// The five networks of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Network {
    GoogleNet,
    SqueezeNet,
    AlexNet,
    ResNet50,
    Vgg19,
}

impl Network {
    pub const ALL: [Network; 5] = [
        Network::GoogleNet,
        Network::SqueezeNet,
        Network::AlexNet,
        Network::ResNet50,
        Network::Vgg19,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Network::GoogleNet => "GoogleNet",
            Network::SqueezeNet => "SqueezeNet",
            Network::AlexNet => "AlexNet",
            Network::ResNet50 => "ResNet-50",
            Network::Vgg19 => "VGG19",
        }
    }

    /// Input size of the full network as the forward engine runs it
    /// ([`crate::net::graphs`]): 224×224×3, except single-tower AlexNet,
    /// whose conv1 (11×11 stride 4, the census-excluded layer) needs
    /// 227×227×3 to produce the canonical 55×55 output.
    pub fn input_size(&self) -> (usize, usize, usize) {
        match self {
            Network::AlexNet => (227, 227, 3),
            _ => (224, 224, 3),
        }
    }

    /// Input size to the last convolutional layer, as listed in Table 1.
    pub fn last_conv_input(&self) -> (usize, usize, usize) {
        match self {
            Network::GoogleNet => (7, 7, 832),
            Network::SqueezeNet => (13, 13, 512),
            Network::AlexNet => (13, 13, 384),
            Network::ResNet50 => (7, 7, 1024),
            Network::Vgg19 => (14, 14, 512),
        }
    }
}

/// One distinct convolution configuration of a network (batch = 1; use
/// [`ConvSpec::with_batch`] to expand).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ZooEntry {
    pub network: Network,
    /// Human-readable layer name, e.g. `inception4e.5x5reduce`.
    pub layer: &'static str,
    pub spec: ConvSpec,
}

/// Batch sizes evaluated in the paper ("1, 8, 16, 32, 64, 128, 256").
pub const BATCH_SIZES: [usize; 7] = [1, 8, 16, 32, 64, 128, 256];

/// All distinct stride-1 configurations of one network.
pub fn network_configs(net: Network) -> Vec<ZooEntry> {
    match net {
        Network::GoogleNet => googlenet::configs(),
        Network::SqueezeNet => squeezenet::configs(),
        Network::AlexNet => alexnet::configs(),
        Network::ResNet50 => resnet50::configs(),
        Network::Vgg19 => vgg19::configs(),
    }
}

/// All 88 distinct configurations across the five networks.
pub fn all_configs() -> Vec<ZooEntry> {
    Network::ALL.iter().flat_map(|&n| network_configs(n)).collect()
}

/// The full evaluation set: every distinct config at every batch size
/// (616 cases).
pub fn all_cases() -> Vec<(ZooEntry, usize)> {
    let mut out = Vec::new();
    for entry in all_configs() {
        for &b in BATCH_SIZES.iter() {
            out.push((entry.clone(), b));
        }
    }
    out
}

/// Census row for Table 1.
#[derive(Debug, Clone, PartialEq)]
pub struct CensusRow {
    pub network: Network,
    pub distinct: usize,
    pub n_1x1: usize,
    pub n_3x3: usize,
    pub n_5x5: usize,
}

impl CensusRow {
    pub fn pct(&self, f: FilterSize) -> f64 {
        let count = match f {
            FilterSize::F1x1 => self.n_1x1,
            FilterSize::F3x3 => self.n_3x3,
            FilterSize::F5x5 => self.n_5x5,
            FilterSize::Other(..) => 0,
        };
        100.0 * count as f64 / self.distinct as f64
    }
}

/// Compute the Table 1 census from the config lists.
pub fn census() -> Vec<CensusRow> {
    Network::ALL
        .iter()
        .map(|&network| {
            let configs = network_configs(network);
            let count =
                |fs: FilterSize| configs.iter().filter(|e| e.spec.filter_size() == fs).count();
            CensusRow {
                network,
                distinct: configs.len(),
                n_1x1: count(FilterSize::F1x1),
                n_3x3: count(FilterSize::F3x3),
                n_5x5: count(FilterSize::F5x5),
            }
        })
        .collect()
}

/// Convenience: entries of a given filter size across all networks,
/// deduplicated by spec (a few shapes repeat across networks).
pub fn configs_with_filter(fs: FilterSize) -> Vec<ZooEntry> {
    let mut seen = std::collections::HashSet::new();
    all_configs()
        .into_iter()
        .filter(|e| e.spec.filter_size() == fs && seen.insert(e.spec))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn census_matches_table1() {
        let rows = census();
        let get = |n: Network| rows.iter().find(|r| r.network == n).unwrap().clone();

        let g = get(Network::GoogleNet);
        assert_eq!((g.distinct, g.n_1x1, g.n_3x3, g.n_5x5), (42, 24, 10, 8));

        let s = get(Network::SqueezeNet);
        assert_eq!((s.distinct, s.n_1x1, s.n_3x3, s.n_5x5), (21, 15, 6, 0));

        let a = get(Network::AlexNet);
        assert_eq!((a.distinct, a.n_1x1, a.n_3x3, a.n_5x5), (4, 0, 3, 1));

        let r = get(Network::ResNet50);
        assert_eq!((r.distinct, r.n_1x1, r.n_3x3, r.n_5x5), (12, 8, 4, 0));

        let v = get(Network::Vgg19);
        assert_eq!((v.distinct, v.n_1x1, v.n_3x3, v.n_5x5), (9, 0, 9, 0));
    }

    #[test]
    fn census_percentages_match_table1() {
        let rows = census();
        let g = rows.iter().find(|r| r.network == Network::GoogleNet).unwrap();
        assert!((g.pct(FilterSize::F1x1) - 57.2).abs() < 0.2);
        assert!((g.pct(FilterSize::F3x3) - 23.8).abs() < 0.2);
        assert!((g.pct(FilterSize::F5x5) - 19.0).abs() < 0.2);
        let a = rows.iter().find(|r| r.network == Network::AlexNet).unwrap();
        assert!((a.pct(FilterSize::F3x3) - 75.0).abs() < 1e-9);
    }

    #[test]
    fn total_cases_exceed_600() {
        assert_eq!(all_configs().len(), 88);
        assert_eq!(all_cases().len(), 88 * 7);
        assert!(all_cases().len() > 600, "paper: 'more than 600'");
    }

    #[test]
    fn all_specs_are_valid_stride1_same_padded() {
        for e in all_configs() {
            assert!(e.spec.is_valid(), "{:?}", e);
            assert_eq!(e.spec.stride, 1, "{:?}", e);
            assert_eq!(e.spec.n, 1, "zoo entries are batch-1: {:?}", e);
            // Same padding => output spatial == input spatial.
            assert_eq!(e.spec.out_h(), e.spec.h, "{:?}", e);
            assert_eq!(e.spec.out_w(), e.spec.w, "{:?}", e);
        }
    }

    #[test]
    fn configs_are_distinct_within_network() {
        for net in Network::ALL {
            let cfgs = network_configs(net);
            let set: std::collections::HashSet<_> =
                cfgs.iter().map(|e| e.spec).collect();
            assert_eq!(set.len(), cfgs.len(), "{net:?} has duplicate configs");
        }
    }

    #[test]
    fn headline_config_is_present() {
        // 7-32-832 — the paper's maximum-speedup configuration (2.29x),
        // inception 5a's 5x5-reduce.
        let found = all_configs()
            .iter()
            .any(|e| e.spec.fig_label() == "7-32-832" && e.spec.kh == 1);
        assert!(found);
    }

    #[test]
    fn profiled_table_configs_are_present() {
        // Tables 3-5 reference these configs (at various batch sizes).
        for label in ["7-256-832", "14-1024-256", "27-256-64", "7-384-192",
                      "13-384-384", "7-128-48"] {
            let found = all_configs().iter().any(|e| e.spec.fig_label() == label);
            assert!(found, "missing profiled config {label}");
        }
    }

    #[test]
    fn filter_queries_cover_all() {
        let n1 = configs_with_filter(FilterSize::F1x1).len();
        let n3 = configs_with_filter(FilterSize::F3x3).len();
        let n5 = configs_with_filter(FilterSize::F5x5).len();
        // Deduplicated across networks, so <= the raw census sums.
        assert!(n1 <= 47 && n1 > 40);
        assert!(n3 <= 32 && n3 > 25);
        assert_eq!(n5, 9);
    }
}
