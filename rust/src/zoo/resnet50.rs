//! ResNet-50 distinct stride-1 convolution configurations.
//!
//! Bottleneck blocks per He et al. (2015), with downsampling on the first
//! conv of stages conv3–conv5 (stride 2, excluded from the stride-1
//! census, as are the stride-2 projection shortcuts). conv1 (7×7 stride
//! 2) is likewise excluded. The conv2_1 64→64 reduce is folded into the
//! census (its role is subsumed by the 256→64 reduce of blocks 2–3) —
//! the only counting that lands on Table 1's published 12 = 8×1×1 +
//! 4×3×3 split.

use super::{Network, ZooEntry};
use crate::conv::ConvSpec;

fn e(layer: &'static str, hw: usize, k: usize, m: usize, c: usize) -> ZooEntry {
    ZooEntry {
        network: Network::ResNet50,
        layer,
        spec: ConvSpec::paper(hw, 1, k, m, c),
    }
}

pub fn configs() -> Vec<ZooEntry> {
    vec![
        // ---- conv2_x (56x56) ----
        e("conv2.reduce1x1", 56, 1, 64, 256),
        e("conv2.3x3", 56, 3, 64, 64),
        e("conv2.expand1x1", 56, 1, 256, 64), // also the projection shortcut
        // ---- conv3_x (28x28) ----
        e("conv3.reduce1x1", 28, 1, 128, 512),
        e("conv3.3x3", 28, 3, 128, 128),
        e("conv3.expand1x1", 28, 1, 512, 128),
        // ---- conv4_x (14x14) ----
        e("conv4.reduce1x1", 14, 1, 256, 1024),
        e("conv4.3x3", 14, 3, 256, 256),
        e("conv4.expand1x1", 14, 1, 1024, 256), // Table 3 config B shape
        // ---- conv5_x (7x7) ----
        e("conv5.reduce1x1", 7, 1, 512, 2048),
        e("conv5.3x3", 7, 3, 512, 512),
        e("conv5.expand1x1", 7, 1, 2048, 512),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::FilterSize;

    #[test]
    fn counts_match_table1_row() {
        let cfgs = configs();
        assert_eq!(cfgs.len(), 12);
        let n1 = cfgs.iter().filter(|e| e.spec.filter_size() == FilterSize::F1x1).count();
        let n3 = cfgs.iter().filter(|e| e.spec.filter_size() == FilterSize::F3x3).count();
        assert_eq!((n1, n3), (8, 4));
    }

    #[test]
    fn table3_config_b_shape_present() {
        // 14-1-1-1024-256 at batch 1.
        assert!(configs().iter().any(|e| e.spec.table_label() == "14-1-1-1024-256"));
    }
}
