//! SqueezeNet v1.0 distinct stride-1 convolution configurations.
//!
//! Derived from Iandola et al. (2016), Table 1: fire2–fire9 squeeze (1×1)
//! and expand (1×1 + 3×3) convs plus conv10, with duplicates listed once.
//! conv1 (7×7 stride 2) is excluded as non-stride-1. Reproduces the
//! paper's 21 = 15×1×1 + 6×3×3 census exactly.

use super::{Network, ZooEntry};
use crate::conv::ConvSpec;

fn e(layer: &'static str, hw: usize, k: usize, m: usize, c: usize) -> ZooEntry {
    ZooEntry {
        network: Network::SqueezeNet,
        layer,
        spec: ConvSpec::paper(hw, 1, k, m, c),
    }
}

pub fn configs() -> Vec<ZooEntry> {
    vec![
        // ---- 55x55 stage (after conv1 + maxpool) ----
        e("fire2.squeeze1x1", 55, 1, 16, 96),
        e("fire2.expand1x1", 55, 1, 64, 16), // == fire3.expand1x1
        e("fire2.expand3x3", 55, 3, 64, 16), // == fire3.expand3x3
        e("fire3.squeeze1x1", 55, 1, 16, 128),
        e("fire4.squeeze1x1", 55, 1, 32, 128),
        e("fire4.expand1x1", 55, 1, 128, 32),
        e("fire4.expand3x3", 55, 3, 128, 32),
        // ---- 27x27 stage (after maxpool4) ----
        e("fire5.squeeze1x1", 27, 1, 32, 256),
        e("fire5.expand1x1", 27, 1, 128, 32),
        e("fire5.expand3x3", 27, 3, 128, 32),
        e("fire6.squeeze1x1", 27, 1, 48, 256),
        e("fire6.expand1x1", 27, 1, 192, 48), // == fire7.expand1x1
        e("fire6.expand3x3", 27, 3, 192, 48), // == fire7.expand3x3
        e("fire7.squeeze1x1", 27, 1, 48, 384),
        e("fire8.squeeze1x1", 27, 1, 64, 384),
        e("fire8.expand1x1", 27, 1, 256, 64), // Table 3 config C shape
        e("fire8.expand3x3", 27, 3, 256, 64),
        // ---- 13x13 stage (after maxpool8) ----
        e("fire9.squeeze1x1", 13, 1, 64, 512),
        e("fire9.expand1x1", 13, 1, 256, 64),
        e("fire9.expand3x3", 13, 3, 256, 64),
        e("conv10", 13, 1, 1000, 512),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::FilterSize;

    #[test]
    fn counts_match_table1_row() {
        let cfgs = configs();
        assert_eq!(cfgs.len(), 21);
        let count = |f: FilterSize| cfgs.iter().filter(|e| e.spec.filter_size() == f).count();
        assert_eq!(count(FilterSize::F1x1), 15);
        assert_eq!(count(FilterSize::F3x3), 6);
        assert_eq!(count(FilterSize::F5x5), 0);
    }

    #[test]
    fn last_conv_input_is_13x13x512() {
        let conv10 = configs().into_iter().find(|e| e.layer == "conv10").unwrap();
        assert_eq!((conv10.spec.h, conv10.spec.c), (13, 512));
    }
}
