//! VGG19 distinct stride-1 convolution configurations.
//!
//! All 16 convs of VGG19 are 3×3 stride-1 same-padded; repeated layers
//! within a stage share a shape, leaving the 9 distinct configurations of
//! Table 1 (100% 3×3).

use super::{Network, ZooEntry};
use crate::conv::ConvSpec;

fn e(layer: &'static str, hw: usize, m: usize, c: usize) -> ZooEntry {
    ZooEntry {
        network: Network::Vgg19,
        layer,
        spec: ConvSpec::paper(hw, 1, 3, m, c),
    }
}

pub fn configs() -> Vec<ZooEntry> {
    vec![
        e("conv1_1", 224, 64, 3),
        e("conv1_2", 224, 64, 64),
        e("conv2_1", 112, 128, 64),
        e("conv2_2", 112, 128, 128),
        e("conv3_1", 56, 256, 128),
        e("conv3_2", 56, 256, 256), // == conv3_3, conv3_4
        e("conv4_1", 28, 512, 256),
        e("conv4_2", 28, 512, 512), // == conv4_3, conv4_4
        e("conv5_1", 14, 512, 512), // == conv5_2..conv5_4
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::FilterSize;

    #[test]
    fn counts_match_table1_row() {
        let cfgs = configs();
        assert_eq!(cfgs.len(), 9);
        assert!(cfgs.iter().all(|e| e.spec.filter_size() == FilterSize::F3x3));
    }

    #[test]
    fn last_conv_input_is_14x14x512() {
        let last = configs().into_iter().last().unwrap();
        assert_eq!((last.spec.h, last.spec.c), (14, 512));
    }
}
