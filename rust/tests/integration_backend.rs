//! Integration: the descriptor → plan → execute API across backends.
//!
//! Pins the acceptance properties of the backend subsystem:
//! * every backend agrees with the `conv_naive` oracle across the
//!   1×1/3×3/5×5 + stride/padding spec set,
//! * plan reuse repeats no planning work (`plan_count` stays flat; the
//!   PJRT `compile_count` twin lives in `integration_runtime.rs`),
//! * `algo_get` always returns an algorithm the backend reports as
//!   supported,
//! * workspace accounting enforces the paper's 1 GB cap.

use cuconv::algo::Algorithm;
use cuconv::backend::{
    algo_find, algo_get, Backend, ConvDescriptor, ConvPlan, CpuRefBackend, Support,
    Workspace,
};
use cuconv::conv::ConvSpec;
use cuconv::cpuref::naive::conv_naive;
use cuconv::tensor::Tensor;
use cuconv::util::rng::Rng;

/// The oracle-agreement spec set: 1x1/3x3/5x5, batching, stride and
/// asymmetric padding.
fn oracle_specs() -> Vec<ConvSpec> {
    vec![
        ConvSpec::paper(7, 2, 1, 8, 16),
        ConvSpec::paper(9, 1, 3, 4, 3),
        ConvSpec::paper(7, 2, 5, 6, 5),
        ConvSpec { stride: 2, pad_h: 0, pad_w: 0, ..ConvSpec::paper(11, 1, 3, 4, 2) },
        ConvSpec { pad_h: 2, pad_w: 1, ..ConvSpec::paper(6, 1, 3, 2, 2) },
    ]
}

fn io(spec: &ConvSpec, seed: u64) -> (Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    let input = Tensor::random(spec.n, spec.c, spec.h, spec.w, &mut rng, -1.0, 1.0);
    let filters = Tensor::random(spec.m, spec.c, spec.kh, spec.kw, &mut rng, -1.0, 1.0);
    (input, filters)
}

/// Every supported (spec, algo) of `backend` must match the oracle.
fn assert_backend_matches_oracle(backend: &dyn Backend, tol: f32) {
    let mut workspace = Workspace::new();
    let mut pairs_tested = 0;
    for spec in oracle_specs() {
        let desc = ConvDescriptor::new(spec).unwrap();
        let (input, filters) = io(&spec, 0xABCD ^ spec.flops());
        let oracle = conv_naive(&spec, &input, &filters);
        for algo in backend.supported_algorithms(&spec) {
            let plan = backend.plan(&desc, algo).unwrap();
            let got = backend.execute(&plan, &input, &filters, &mut workspace).unwrap();
            let err = got.rel_l2_error(&oracle);
            assert!(
                err < tol,
                "{}::{algo} vs oracle: rel_l2={err} on {spec}",
                backend.name()
            );
            pairs_tested += 1;
        }
    }
    assert!(pairs_tested > 0, "{} supported nothing", backend.name());
}

#[test]
fn cpuref_backend_agrees_with_oracle_across_spec_set() {
    assert_backend_matches_oracle(&CpuRefBackend::new(), 2e-5);
}

/// The serving shape of the tiled path through the public API only:
/// plan once **with** the layer's filters, execute many times into
/// reused buffers — every execute takes the packed fast path, outputs
/// are bit-identical to the oracle, and the workspace is never touched
/// (the microkernel's scratch is its register tile).
#[test]
fn packed_cuconv_plans_serve_tiled_bit_exact_and_workspace_free() {
    let backend = CpuRefBackend::new();
    let mut workspace = Workspace::new();
    for spec in oracle_specs() {
        let desc = ConvDescriptor::new(spec).unwrap();
        let (input, filters) = io(&spec, 0x717ED ^ spec.flops());
        let oracle = conv_naive(&spec, &input, &filters);
        let filters = std::sync::Arc::new(filters);
        let plan = backend.plan_with_filters(&desc, Algorithm::CuConv, &filters).unwrap();
        assert!(plan.packed_filters().is_some(), "no packed weights for {spec}");
        assert_eq!(plan.workspace_bytes(), 0);
        let [n, m, oh, ow] = spec.output_shape();
        let mut out = Tensor::full(n, m, oh, ow, f32::NAN); // dirty reuse
        let before = backend.packed_execute_count();
        for _ in 0..3 {
            backend
                .execute_into(&plan, &input, &filters, &mut workspace, &mut out)
                .unwrap();
            assert_eq!(
                out.max_abs_diff(&oracle),
                0.0,
                "tiled serving not bit-identical on {spec}"
            );
        }
        assert_eq!(
            backend.packed_execute_count(),
            before + 3,
            "an execute missed the packed fast path on {spec}"
        );
    }
    assert_eq!(workspace.high_water_bytes(), 0, "tiled path must not touch scratch");
}

#[test]
fn cpuref_plan_reuse_repeats_no_planning() {
    let backend = CpuRefBackend::new();
    let spec = ConvSpec::paper(9, 1, 3, 4, 3);
    let desc = ConvDescriptor::new(spec).unwrap();
    let (input, filters) = io(&spec, 7);
    let mut workspace = Workspace::new();
    let plan = backend.plan(&desc, Algorithm::CuConv).unwrap();
    let baseline = backend.plan_count();
    for _ in 0..10 {
        backend.execute(&plan, &input, &filters, &mut workspace).unwrap();
    }
    assert_eq!(
        backend.plan_count(),
        baseline,
        "execute must not plan; plan reuse keeps plan_count flat"
    );
}

#[test]
fn algo_get_always_returns_a_supported_algorithm() {
    // Across the whole zoo (every distinct config, three batch sizes):
    // the contract is unconditional.
    let backend = CpuRefBackend::new();
    for entry in cuconv::zoo::all_configs() {
        for batch in [1usize, 8, 64] {
            let spec = entry.spec.with_batch(batch);
            let desc = ConvDescriptor::new(spec).unwrap();
            let algo = algo_get(&backend, &desc).unwrap();
            assert!(
                backend.capabilities(&spec, algo).is_supported(),
                "algo_get returned unsupported {algo} for {spec}"
            );
        }
    }
}

#[test]
fn algo_find_best_is_executable_and_ranked() {
    let backend = CpuRefBackend::new();
    let spec = ConvSpec::paper(8, 1, 3, 4, 4);
    let desc = ConvDescriptor::new(spec).unwrap();
    let result = algo_find(&backend, &desc, 2);
    assert!(!result.entries.is_empty());
    for w in result.entries.windows(2) {
        assert!(w[0].score_us <= w[1].score_us);
    }
    // The winner must actually execute.
    let best = result.best().unwrap().algo;
    let plan = backend.plan(&desc, best).unwrap();
    let (input, filters) = io(&spec, 11);
    let mut workspace = Workspace::new();
    backend.execute(&plan, &input, &filters, &mut workspace).unwrap();
}

#[test]
fn workspace_cap_blocks_oversized_plans() {
    let backend = CpuRefBackend::new();
    // VGG-scale conv at batch 256: FFT spectra blow the 1 GB cap.
    let spec = ConvSpec::paper(224, 256, 3, 64, 64);
    assert_eq!(
        backend.capabilities(&spec, Algorithm::Fft),
        Support::Unsupported("workspace above the 1 GB cap")
    );
    let desc = ConvDescriptor::new(spec).unwrap();
    assert!(backend.plan(&desc, Algorithm::Fft).is_err());
    // The workspace object itself also refuses a direct oversized ask.
    let mut ws = Workspace::new();
    assert!(ws.ensure_bytes(Algorithm::Fft.workspace_bytes(&spec)).is_err());
}

#[test]
fn workspace_is_reused_and_tracks_high_water() {
    let backend = CpuRefBackend::new();
    let mut workspace = Workspace::new();
    // Execute an explicit-GEMM conv (carves the im2col matrix from the
    // workspace) then a zero-scratch fused cuConv: capacity must be
    // retained, high-water must reflect the larger ask.
    let s3 = ConvSpec::paper(9, 1, 3, 4, 3);
    let s1 = ConvSpec::paper(7, 1, 1, 8, 16);
    let desc3 = ConvDescriptor::new(s3).unwrap();
    let gemm_plan = backend.plan(&desc3, Algorithm::GemmExplicit).unwrap();
    let gemm_bytes = gemm_plan.workspace_bytes();
    assert!(gemm_bytes > 0, "explicit GEMM must carve real scratch");
    let (input, filters) = io(&s3, 5);
    backend.execute(&gemm_plan, &input, &filters, &mut workspace).unwrap();
    // The fused cuConv path needs no scratch at all (the stage-1
    // temporary of the staged algorithm is eliminated).
    let desc1 = ConvDescriptor::new(s1).unwrap();
    let cu_plan = backend.plan(&desc1, Algorithm::CuConv).unwrap();
    assert_eq!(cu_plan.workspace_bytes(), 0);
    let (input, filters) = io(&s1, 5);
    backend.execute(&cu_plan, &input, &filters, &mut workspace).unwrap();
    assert_eq!(workspace.high_water_bytes(), gemm_bytes);
    assert!(workspace.capacity_bytes() >= gemm_bytes);
}

/// Steady-state serving is allocation-free: once a plan has executed
/// once, 100 further executes on the same plan grow neither the
/// workspace high-water mark nor its capacity — all scratch is carved
/// from the existing reservation (and the output tensor is reused via
/// `execute_into`). Checked for every algorithm the backend supports.
#[test]
fn workspace_high_water_stays_flat_across_repeated_executes() {
    let backend = CpuRefBackend::new();
    let spec = ConvSpec::paper(9, 1, 3, 4, 3);
    let desc = ConvDescriptor::new(spec).unwrap();
    let (input, filters) = io(&spec, 21);
    let [n, m, oh, ow] = spec.output_shape();
    for algo in backend.supported_algorithms(&spec) {
        let plan = backend.plan(&desc, algo).unwrap();
        let mut workspace = Workspace::new();
        let mut out = Tensor::zeros(n, m, oh, ow);
        backend.execute_into(&plan, &input, &filters, &mut workspace, &mut out).unwrap();
        let high_water = workspace.high_water_bytes();
        let capacity = workspace.capacity_bytes();
        assert_eq!(high_water, plan.workspace_bytes(), "{algo}: first execute sizes it");
        for _ in 0..100 {
            backend
                .execute_into(&plan, &input, &filters, &mut workspace, &mut out)
                .unwrap();
        }
        assert_eq!(
            workspace.high_water_bytes(),
            high_water,
            "{algo}: high-water grew across repeated executes"
        );
        assert_eq!(
            workspace.capacity_bytes(),
            capacity,
            "{algo}: workspace reallocated across repeated executes"
        );
    }
}

#[test]
fn plans_are_stamped_with_their_backend() {
    let backend = CpuRefBackend::new();
    let spec = ConvSpec::paper(7, 1, 1, 8, 16);
    let desc = ConvDescriptor::new(spec).unwrap();
    let plan = backend.plan(&desc, Algorithm::CuConv).unwrap();
    assert_eq!(plan.backend_name(), "cpuref");
    assert_eq!(plan.algo(), Algorithm::CuConv);
    assert_eq!(plan.workspace_bytes(), 0, "1x1 cuconv skips stage 2");
    // A foreign (opaque) plan is refused at execute time.
    let foreign = ConvPlan::new_opaque("elsewhere", spec, Algorithm::CuConv, "k0");
    let (input, filters) = io(&spec, 6);
    let mut workspace = Workspace::new();
    assert!(backend.execute(&foreign, &input, &filters, &mut workspace).is_err());
}

/// With `--features pjrt` and built artifacts, the PJRT backend must
/// pass the same oracle sweep on whatever artifacts exist.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_backend_agrees_with_oracle_where_artifacts_exist() {
    let dir = cuconv::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let backend = cuconv::backend::PjrtBackend::from_dir(&dir).unwrap();
    let mut workspace = Workspace::new();
    let mut tested = 0;
    for artifact in backend.manifest().convs.clone() {
        let Some(algo) = Algorithm::from_name(&artifact.algo) else { continue };
        let spec = artifact.spec;
        if !backend.capabilities(&spec, algo).is_supported() {
            continue;
        }
        let desc = ConvDescriptor::new(spec).unwrap();
        let plan = backend.plan(&desc, algo).unwrap();
        let (input, filters) = io(&spec, 0xF00D ^ spec.flops());
        let oracle = conv_naive(&spec, &input, &filters);
        let got = backend.execute(&plan, &input, &filters, &mut workspace).unwrap();
        assert!(
            got.rel_l2_error(&oracle) < 5e-4,
            "pjrt::{algo} disagrees with oracle on {spec}"
        );
        tested += 1;
        if tested >= 12 {
            break; // bounded runtime; coverage across algorithms suffices
        }
    }
    assert!(tested > 0, "no conv artifacts were testable");
}
