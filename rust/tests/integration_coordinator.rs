//! Integration: the serving coordinator end to end.
//!
//! The conv-backend serving path (a convolution layer through the
//! [`Backend`](cuconv::backend::Backend) API) runs on every build; the
//! AOT-model path additionally needs the `pjrt` feature and built
//! artifacts (skipped with a note otherwise).

use std::time::Duration;

use cuconv::backend::CpuRefBackend;
use cuconv::conv::ConvSpec;
use cuconv::coordinator::{BatchPolicy, PoolConfig, Server, ServerBuilder, ShardSelection};
use cuconv::util::rng::Rng;

fn image(rng: &mut Rng, elems: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; elems];
    rng.fill_uniform(&mut v, -1.0, 1.0);
    v
}

/// A conv-layer worker pool over the CPU reference backend — no
/// artifacts.
fn conv_pool(policy: BatchPolicy, pool: PoolConfig) -> Server {
    let spec = ConvSpec::paper(8, 1, 3, 4, 4);
    ServerBuilder::conv(Box::new(CpuRefBackend::new()), spec, &[1, 2, 4, 8])
        .policy(policy)
        .pool(pool)
        .start()
        .unwrap()
}

/// Single-worker convenience used by the pre-pool tests.
fn conv_server(policy: BatchPolicy) -> Server {
    conv_pool(policy, PoolConfig::default())
}

#[test]
fn conv_server_serves_single_request() {
    let server = conv_server(BatchPolicy::default());
    let h = server.handle();
    let mut rng = Rng::new(1);
    let resp = h.infer(image(&mut rng, h.image_elems())).unwrap();
    assert_eq!(resp.logits.len(), h.classes());
    assert!(resp.total_seconds > 0.0);
    assert!(resp.batch_size >= 1);
}

#[test]
fn conv_server_rejects_wrong_image_size() {
    let server = conv_server(BatchPolicy::default());
    assert!(server.handle().infer(vec![0.0; 7]).is_err());
}

#[test]
fn conv_server_batches_concurrent_requests() {
    let policy = BatchPolicy {
        max_batch: 8,
        max_delay: Duration::from_millis(30),
        queue_capacity: 64,
    };
    let server = conv_server(policy);
    let h = server.handle();
    let elems = h.image_elems();

    // Fire 16 requests concurrently; the router should form multi-image
    // batches (plans exist for batch sizes 1,2,4,8).
    std::thread::scope(|s| {
        for t in 0..16u64 {
            let h = h.clone();
            s.spawn(move || {
                let mut rng = Rng::new(t);
                let resp = h.infer(image(&mut rng, elems)).unwrap();
                assert_eq!(resp.logits.len(), h.classes());
            });
        }
    });
    let snap = server.metrics();
    assert_eq!(snap.requests, 16);
    assert!(
        snap.mean_batch_size > 1.0,
        "dynamic batching never batched (mean={})",
        snap.mean_batch_size
    );
    assert!(snap.throughput_rps > 0.0);
}

#[test]
fn conv_server_solo_vs_batched_outputs_agree() {
    // The same pixels must produce the same conv output whether served
    // alone or inside a batch — the batcher must not mix rows up, and
    // the runner's per-size plans must agree numerically.
    let policy = BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_millis(20),
        queue_capacity: 64,
    };
    let server = conv_server(policy);
    let h = server.handle();
    let mut rng = Rng::new(99);
    let img = image(&mut rng, h.image_elems());

    let solo = h.infer(img.clone()).unwrap();

    let batched = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let h2 = h.clone();
            let elems = h.image_elems();
            let img2 =
                if t == 0 { img.clone() } else { image(&mut Rng::new(1000 + t), elems) };
            handles.push(s.spawn(move || h2.infer(img2).unwrap()));
        }
        handles.remove(0).join().unwrap()
    });
    for (a, b) in solo.logits.iter().zip(batched.logits.iter()) {
        assert!((a - b).abs() < 1e-4, "solo {a} vs batched {b}");
    }
}

#[test]
fn conv_server_backpressure_rejects_when_flooded() {
    let policy = BatchPolicy {
        max_batch: 1,
        max_delay: Duration::from_millis(1),
        queue_capacity: 2,
    };
    let server = conv_server(policy);
    let h = server.handle();
    let elems = h.image_elems();
    let mut rng = Rng::new(3);

    let mut accepted = Vec::new();
    let mut rejected = 0;
    for _ in 0..64 {
        match h.submit(image(&mut rng, elems)) {
            Ok(rx) => accepted.push(rx),
            Err(_) => rejected += 1,
        }
    }
    for rx in accepted {
        let _ = rx.recv();
    }
    let snap = server.metrics();
    assert_eq!(snap.rejected as usize, rejected);
}

#[test]
fn pool_outputs_bit_identical_to_single_worker() {
    // The sharded-serving determinism contract: the same pixels produce
    // the same logits — bit for bit — whether the pool has one worker
    // or four, because replicas share the seeded filters and pinned
    // algorithm choices and every kernel processes items independently.
    let policy = BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_millis(5),
        queue_capacity: 64,
    };
    let single = conv_pool(policy, PoolConfig::with_workers(1));
    let pool = conv_pool(policy, PoolConfig::with_workers(4));
    let h1 = single.handle();
    let h4 = pool.handle();
    assert_eq!(pool.workers(), 4);

    let mut rng = Rng::new(2024);
    for i in 0..6 {
        let img = image(&mut rng, h1.image_elems());
        let a = h1.infer(img.clone()).unwrap();
        let b = h4.infer(img).unwrap();
        assert_eq!(a.logits, b.logits, "request {i}: pool diverged from single worker");
    }
}

#[test]
fn pool_concurrent_load_is_bit_identical_too() {
    // Same contract under concurrency: fire the same image through a
    // 3-worker pool from many threads alongside decoys; every reply for
    // the pinned image must be bit-identical to the solo answer.
    let policy = BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_millis(10),
        queue_capacity: 64,
    };
    let pool = conv_pool(policy, PoolConfig::with_workers(3));
    let h = pool.handle();
    let elems = h.image_elems();
    let mut rng = Rng::new(7);
    let img = image(&mut rng, elems);
    let want = h.infer(img.clone()).unwrap().logits;

    let echoes: Vec<Vec<f32>> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..12u64)
            .map(|t| {
                let h = h.clone();
                let img = if t % 2 == 0 {
                    img.clone()
                } else {
                    image(&mut Rng::new(5000 + t), elems)
                };
                let keep = t % 2 == 0;
                s.spawn(move || {
                    let logits = h.infer(img).unwrap().logits;
                    keep.then_some(logits)
                })
            })
            .collect();
        joins.into_iter().filter_map(|j| j.join().unwrap()).collect()
    });
    assert_eq!(echoes.len(), 6);
    for (i, e) in echoes.iter().enumerate() {
        assert_eq!(e, &want, "echo {i} diverged under concurrent sharding");
    }
}

#[test]
fn pool_round_robin_spreads_requests_across_workers() {
    let policy = BatchPolicy {
        max_batch: 1,
        max_delay: Duration::from_millis(1),
        queue_capacity: 8,
    };
    let pool = conv_pool(
        policy,
        PoolConfig { workers: 4, selection: ShardSelection::RoundRobin, ..PoolConfig::default() },
    );
    let h = pool.handle();
    let mut rng = Rng::new(11);
    // Sequential blocking requests: the round-robin cursor must rotate
    // through all four shards.
    for _ in 0..8 {
        h.infer(image(&mut rng, h.image_elems())).unwrap();
    }
    let per_worker = pool.worker_metrics();
    assert_eq!(per_worker.len(), 4);
    assert_eq!(per_worker.iter().map(|w| w.requests).sum::<u64>(), 8);
    for (i, w) in per_worker.iter().enumerate() {
        assert_eq!(w.requests, 2, "worker {i} did not get its round-robin share");
    }
    // The aggregate view equals the sum of the shards.
    assert_eq!(pool.metrics().requests, 8);
}

#[test]
fn pool_backpressure_rejects_only_when_every_queue_is_full() {
    let policy = BatchPolicy {
        max_batch: 1,
        max_delay: Duration::from_millis(1),
        queue_capacity: 1,
    };
    let pool = conv_pool(policy, PoolConfig::with_workers(2));
    let h = pool.handle();
    let elems = h.image_elems();
    let mut rng = Rng::new(13);

    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for _ in 0..64 {
        match h.submit(image(&mut rng, elems)) {
            Ok(rx) => accepted.push(rx),
            Err(_) => rejected += 1,
        }
    }
    for rx in accepted {
        let _ = rx.recv();
    }
    let snap = pool.metrics();
    assert_eq!(snap.rejected, rejected, "dispatcher rejections must be surfaced");
    assert_eq!(snap.requests + rejected, 64, "every submission accounted once");
}

#[test]
fn net_pool_matches_single_worker_bit_for_bit() {
    // Whole-network sharding: NetForwardRunner replicas (one NetPlan
    // replica per batch size, shared weights, private arenas) must
    // serve logits bit-identical to the single-worker path.
    use cuconv::net::GraphBuilder;

    let graph = {
        let mut b = GraphBuilder::new("pool-net", 2, 10, 10);
        let c1 = b.conv_same("c1", b.input(), 6, 3);
        let p = b.max_pool("p", c1, 2, 2, 0);
        let c2 = b.conv_same("c2", p, 8, 3);
        let g = b.global_avg_pool("gap", c2);
        let fc = b.linear("fc", g, 7, false);
        b.softmax("sm", fc);
        b.finish()
    };
    let policy = BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_millis(5),
        queue_capacity: 32,
    };
    let single = ServerBuilder::net(Box::new(CpuRefBackend::new()), &graph, &[1, 2, 4])
        .policy(policy)
        .pool(PoolConfig::with_workers(1))
        .start()
        .unwrap();
    let pool = ServerBuilder::net(Box::new(CpuRefBackend::new()), &graph, &[1, 2, 4])
        .policy(policy)
        .pool(PoolConfig::with_workers(3))
        .start()
        .unwrap();
    let h1 = single.handle();
    let h3 = pool.handle();
    let mut rng = Rng::new(42);
    for i in 0..4 {
        let img = image(&mut rng, h1.image_elems());
        let a = h1.infer(img.clone()).unwrap();
        let b = h3.infer(img).unwrap();
        assert_eq!(a.logits.len(), 7);
        assert_eq!(a.logits, b.logits, "request {i}: net pool diverged");
    }
}

#[test]
fn conv_server_shutdown_is_clean() {
    let mut server = conv_server(BatchPolicy::default());
    let h = server.handle();
    let mut rng = Rng::new(5);
    let _ = h.infer(image(&mut rng, h.image_elems())).unwrap();
    server.shutdown();
    // Further submissions fail cleanly.
    assert!(h.infer(image(&mut rng, h.image_elems())).is_err());
}

/// Worker supervision, priority-aware shedding, and the fault-injection
/// harness — the chaos contract at integration scope.
mod fault_tolerance {
    use super::*;
    use anyhow::Result;
    use cuconv::coordinator::{
        run_closed_loop_mixed, BatchOutput, BatchRunner, ConvBackendRunner, Fault,
        FaultInjector, FaultPlan, Priority, Server, ServerHandle, SubmitError,
    };
    use cuconv::util::prop::{assert_prop, Config, PairOf, UsizeIn};

    /// The faulted pools in this module plan batch sizes 1/2/4 (not the
    /// outer `conv_pool`'s 1/2/4/8) so a reference pool built here is
    /// plan-for-plan identical to the pool under fault injection.
    fn faultable_runner() -> ConvBackendRunner {
        ConvBackendRunner::new(
            Box::new(CpuRefBackend::new()),
            ConvSpec::paper(8, 1, 3, 4, 4),
            None,
            &[1, 2, 4],
        )
        .unwrap()
    }

    fn faulted_pool(plan: FaultPlan, workers: usize) -> Server {
        let faulty = FaultInjector::new(Box::new(faultable_runner()), plan);
        ServerBuilder::runner(Box::new(faulty))
            .pool(PoolConfig::with_workers(workers))
            .start()
            .unwrap()
    }

    /// Client-side offered must equal the server's four-way accounting
    /// for every priority class — the zero-lost contract.
    fn assert_zero_lost(
        report: &cuconv::coordinator::ClassReport,
        m: &cuconv::coordinator::MetricsSnapshot,
    ) {
        for snap in &m.per_class {
            let r = report.class(snap.priority);
            assert_eq!(
                r.offered() as u64,
                snap.offered(),
                "{}: client offered {} but server accounted {} \
                 (completed {} rejected {} failed {} expired {})",
                snap.priority,
                r.offered(),
                snap.offered(),
                snap.completed,
                snap.rejected,
                snap.failed,
                snap.expired,
            );
        }
    }

    /// One seeded probe served at batch 1 through `h`, bitwise.
    fn probe_bits(h: &ServerHandle, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        let img = image(&mut rng, h.image_elems());
        h.infer(img).unwrap().logits.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn supervised_pool_recovers_from_injected_panic() {
        let plan = FaultPlan::new(vec![Fault::Panic { worker: 0, request: 2 }]);
        let server = faulted_pool(plan, 2);

        let report =
            run_closed_loop_mixed(&server.handle(), 32, 4, 0xFA11_5EED, None, 0.5);
        let m = server.metrics();

        assert_eq!(m.restarts, 1, "the panicked shard must be respawned exactly once");
        assert_eq!(
            m.failed, 0,
            "the panicked shard's queue must be requeued, not failed"
        );
        assert_eq!(report.completed(), 32, "every request must still complete");
        assert_zero_lost(&report, &m);
        assert_eq!(
            server.live_workers(),
            server.workers(),
            "the pool must be back to full strength"
        );
        assert!(m.restart_max_seconds >= 0.0 && m.restart_max_seconds.is_finite());

        // Post-recovery numerics: bit-identical to a never-faulted
        // single-worker pool.
        let reference = ServerBuilder::runner(Box::new(faultable_runner()))
            .pool(PoolConfig::with_workers(1))
            .start()
            .unwrap();
        for seed in [7u64, 8, 9] {
            assert_eq!(
                probe_bits(&server.handle(), seed),
                probe_bits(&reference.handle(), seed),
                "seed {seed}: recovered pool diverged from the unfaulted reference"
            );
        }
    }

    #[test]
    fn stall_is_survived_without_a_restart() {
        let plan =
            FaultPlan::new(vec![Fault::Stall { worker: 0, request: 1, millis: 30 }]);
        let server = faulted_pool(plan, 2);
        let report =
            run_closed_loop_mixed(&server.handle(), 24, 4, 0x57A1_1u64, None, 0.5);
        let m = server.metrics();
        assert_eq!(m.restarts, 0, "a stall is not a crash");
        assert_eq!(report.completed(), 24);
        assert_zero_lost(&report, &m);
    }

    /// A runner whose first execution panics — for exercising the
    /// *unsupervised* path and the shutdown join accounting.
    struct Exploder;

    impl BatchRunner for Exploder {
        fn batch_sizes(&self) -> Vec<usize> {
            vec![1]
        }
        fn item_in_elems(&self) -> usize {
            2
        }
        fn item_out_elems(&self) -> usize {
            2
        }
        fn run(&mut self, _batch: usize, _input: Vec<f32>) -> Result<BatchOutput> {
            panic!("exploder: always panics");
        }
    }

    #[test]
    fn unsupervised_panic_is_answered_and_counted_at_shutdown() {
        // Regression for the silent `let _ = w.join()` swallow: a
        // worker that dies unsupervised must (1) answer its in-flight
        // requests with an error instead of dropping them, (2) show up
        // in live_workers, and (3) be counted as a panicked join at
        // shutdown rather than ignored.
        let mut server = ServerBuilder::runner(Box::new(Exploder))
            .policy(BatchPolicy {
                max_batch: 1,
                max_delay: Duration::from_millis(1),
                queue_capacity: 4,
            })
            .pool(PoolConfig { workers: 1, supervise: false, ..PoolConfig::default() })
            .start()
            .unwrap();
        let h = server.handle();

        let first = h.infer(vec![0.0; 2]);
        assert!(first.is_err(), "a panicked worker must answer with an error, not hang");
        let err = format!("{}", first.unwrap_err());
        assert!(
            err.contains("panic"),
            "the error should say the worker panicked, got: {err}"
        );
        assert_eq!(server.live_workers(), 0, "the dead worker must leave the live count");
        assert!(h.infer(vec![0.0; 2]).is_err(), "a dead pool must reject, not hang");

        let m = server.metrics();
        assert_eq!(m.failed, 1, "the in-flight request must be accounted as failed");

        server.shutdown();
        assert_eq!(
            server.panicked_joins(),
            1,
            "shutdown must surface the panicked join instead of swallowing it"
        );
    }

    #[test]
    fn prop_accounting_holds_under_any_fault_schedule() {
        // For any seeded panic/stall schedule: every class's accounting
        // identity holds on both sides of the wire, nothing is served
        // twice, and the pool still answers bit-identically to an
        // unfaulted single-worker reference afterwards.
        let gen = PairOf(UsizeIn { lo: 0, hi: 1_000_000 }, UsizeIn { lo: 2, hi: 3 });
        let config = Config { cases: 5, seed: 0xFA57_C0DE, max_shrink_steps: 10 };
        assert_prop(config, &gen, |&(seed, workers)| {
            let plan = FaultPlan::random(seed as u64, workers, 3, 16);
            let panics = plan
                .faults
                .iter()
                .filter(|f| matches!(f, Fault::Panic { .. }))
                .count() as u64;
            let server = faulted_pool(plan, workers);
            let report = run_closed_loop_mixed(
                &server.handle(),
                24,
                4,
                seed as u64 ^ 0xD1CE,
                None,
                0.5,
            );
            let m = server.metrics();

            let mut completed_total = 0u64;
            for p in Priority::ALL {
                let r = report.class(p);
                if r.offered() != r.completed + r.rejected + r.failed + r.expired {
                    return Err(format!("{p}: client four-way accounting broken"));
                }
                let snap = m
                    .per_class
                    .iter()
                    .find(|s| s.priority == p)
                    .ok_or_else(|| format!("{p}: missing server class row"))?;
                if snap.offered() != r.offered() as u64 {
                    return Err(format!(
                        "{p}: lost requests — client offered {} vs server {}",
                        r.offered(),
                        snap.offered()
                    ));
                }
                completed_total += snap.completed;
            }
            if m.requests != completed_total {
                return Err(format!(
                    "double-serve: {} served vs {} completed",
                    m.requests, completed_total
                ));
            }
            if m.restarts > panics {
                return Err(format!(
                    "{} restarts from only {panics} planned panics",
                    m.restarts
                ));
            }

            let reference = ServerBuilder::runner(Box::new(faultable_runner()))
                .pool(PoolConfig::with_workers(1))
                .start()
                .unwrap();
            if probe_bits(&server.handle(), 0xB17) != probe_bits(&reference.handle(), 0xB17)
            {
                return Err("post-schedule output diverged from reference".to_string());
            }
            Ok(())
        });
    }

    /// Poll `probe` every 2 ms until it holds or `timeout` passes.
    fn wait_until(timeout: Duration, mut probe: impl FnMut() -> bool) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while std::time::Instant::now() < deadline {
            if probe() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        probe()
    }

    #[test]
    fn stalled_worker_is_evicted_fenced_and_pool_recovers() {
        // A worker hung 8x past the stall budget is a stall to evict,
        // not a slow batch: the watchdog fences it, requeues its work,
        // and respawns a replacement; its late completion is discarded
        // and counted, never double-served.
        let plan =
            FaultPlan::new(vec![Fault::Stall { worker: 0, request: 0, millis: 400 }]);
        let faulty = FaultInjector::new(Box::new(faultable_runner()), plan);
        let server = ServerBuilder::runner(Box::new(faulty))
            .pool(PoolConfig {
                workers: 2,
                selection: ShardSelection::RoundRobin,
                stall_budget: Duration::from_millis(50),
                ..PoolConfig::default()
            })
            .start()
            .unwrap();

        let report =
            run_closed_loop_mixed(&server.handle(), 24, 4, 0xE71C_7ED, None, 0.5);
        let m = server.metrics();

        assert!(
            m.stalled_evictions >= 1,
            "the watchdog must evict the hung worker ({} evictions)",
            m.stalled_evictions
        );
        assert!(
            m.restarts >= m.stalled_evictions,
            "every eviction must respawn a replacement"
        );
        assert_eq!(
            report.completed(),
            24,
            "the stalled request must be requeued and answered, not dropped"
        );
        assert_zero_lost(&report, &m);
        assert_eq!(
            server.live_workers(),
            server.workers(),
            "the pool must be back to full strength after the eviction"
        );

        // The hung incarnation wakes at ~400 ms and hits the fence: its
        // late completion must be discarded and counted.
        assert!(
            wait_until(Duration::from_secs(5), || {
                server.metrics().fenced_discards >= 1
            }),
            "the evicted worker's late completion was never fenced off"
        );

        // Post-eviction numerics: bit-identical to a never-faulted pool.
        let reference = ServerBuilder::runner(Box::new(faultable_runner()))
            .pool(PoolConfig::with_workers(1))
            .start()
            .unwrap();
        for seed in [17u64, 18] {
            assert_eq!(
                probe_bits(&server.handle(), seed),
                probe_bits(&reference.handle(), seed),
                "seed {seed}: recovered pool diverged from the unfaulted reference"
            );
        }
    }

    #[test]
    fn short_stall_under_budget_is_not_evicted() {
        // A batch merely slower than usual must ride out: no eviction,
        // no restart, no fenced discard.
        let plan =
            FaultPlan::new(vec![Fault::Stall { worker: 0, request: 1, millis: 40 }]);
        let faulty = FaultInjector::new(Box::new(faultable_runner()), plan);
        let server = ServerBuilder::runner(Box::new(faulty))
            .pool(PoolConfig {
                workers: 2,
                stall_budget: Duration::from_millis(500),
                ..PoolConfig::default()
            })
            .start()
            .unwrap();
        let report =
            run_closed_loop_mixed(&server.handle(), 24, 4, 0x510_57A1, None, 0.5);
        let m = server.metrics();
        assert_eq!(m.stalled_evictions, 0, "a 40 ms stall is under the 500 ms budget");
        assert_eq!(m.restarts, 0, "nothing to respawn");
        assert_eq!(m.fenced_discards, 0, "nothing was fenced");
        assert_eq!(report.completed(), 24);
        assert_zero_lost(&report, &m);
    }

    #[test]
    fn shutdown_during_stall_is_bounded_and_counts_the_hung_join() {
        // Drain with a worker hung past every budget: shutdown must
        // return within drain budget + join grace — never wait
        // unboundedly — and surface the abandoned join in the count.
        let plan =
            FaultPlan::new(vec![Fault::Stall { worker: 0, request: 0, millis: 2_000 }]);
        let faulty = FaultInjector::new(Box::new(faultable_runner()), plan);
        let mut server = ServerBuilder::runner(Box::new(faulty))
            .pool(PoolConfig {
                workers: 1,
                drain_budget: Duration::from_millis(100),
                ..PoolConfig::default()
            })
            .start()
            .unwrap();
        let h = server.handle();

        // Park one request on the worker; the injected stall hangs it
        // for 2 s — well past the 100 ms drain budget and the 1 s join
        // grace, but under the default 5 s stall budget (no eviction:
        // this is the drain path, not the watchdog path).
        let elems = h.image_elems();
        let probe = std::thread::spawn(move || h.infer(vec![0.1f32; elems]));
        assert!(
            wait_until(Duration::from_secs(2), || {
                server.handle().aggregate_inflight() > 0
            }),
            "the probe request never reached the worker"
        );

        let started = std::time::Instant::now();
        server.shutdown();
        let elapsed = started.elapsed();
        assert!(
            elapsed < Duration::from_secs(3),
            "shutdown took {elapsed:?} — it must be bounded, not wait out a 2 s hang"
        );
        assert_eq!(
            server.abandoned_joins(),
            1,
            "the hung worker's join must be counted as abandoned, not waited on"
        );
        // The detached thread wakes at ~2 s and exits on its own; the
        // probe's reply (whatever it is) must arrive rather than hang.
        let _ = probe.join().expect("probe thread");
    }

    #[test]
    fn draining_rejects_new_submissions() {
        // While the drain window is open (admission closed, queued work
        // finishing), new submissions must get `SubmitError::Shutdown`
        // — and be counted rejected — not sneak into the pool.
        let plan =
            FaultPlan::new(vec![Fault::Stall { worker: 0, request: 0, millis: 600 }]);
        let faulty = FaultInjector::new(Box::new(faultable_runner()), plan);
        let mut server = ServerBuilder::runner(Box::new(faulty))
            .pool(PoolConfig {
                workers: 1,
                drain_budget: Duration::from_millis(400),
                ..PoolConfig::default()
            })
            .start()
            .unwrap();
        let h = server.handle();
        let elems = h.image_elems();
        let probe_h = server.handle();
        let probe = std::thread::spawn(move || probe_h.infer(vec![0.2f32; elems]));
        assert!(
            wait_until(Duration::from_secs(2), || h.aggregate_inflight() > 0),
            "the probe request never reached the worker"
        );

        // Submit from a side thread the moment draining flips on; the
        // 600 ms stall holds the drain window open past the check.
        let checker_h = server.handle();
        let checker = std::thread::spawn(move || {
            if !wait_until(Duration::from_secs(2), || checker_h.draining()) {
                return Err("draining never became visible".to_string());
            }
            let elems = checker_h.image_elems();
            match checker_h.submit_request(vec![0.3f32; elems], None) {
                Err(SubmitError::Shutdown) => Ok(()),
                other => Err(format!(
                    "expected Err(Shutdown) during drain, got {:?}",
                    other.map(|_| "Ok(receiver)")
                )),
            }
        });
        server.shutdown();
        checker.join().expect("checker thread").unwrap();
        let _ = probe.join().expect("probe thread");
        assert!(server.handle().draining(), "draining stays visible after shutdown");
    }
}

/// The AOT-model serving path (needs `--features pjrt` + artifacts).
#[cfg(feature = "pjrt")]
mod pjrt_model {
    use super::*;
    use cuconv::coordinator::ServerConfig;
    use cuconv::runtime::Manifest;

    fn manifest() -> Option<Manifest> {
        let dir = cuconv::runtime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Manifest::load(dir).unwrap())
    }

    #[test]
    fn serves_single_request() {
        let Some(m) = manifest() else { return };
        let server = Server::start(m, ServerConfig::default()).unwrap();
        let h = server.handle();
        let mut rng = Rng::new(1);
        let resp = h.infer(image(&mut rng, h.image_elems())).unwrap();
        assert_eq!(resp.logits.len(), h.classes());
        assert!(resp.total_seconds > 0.0);
        assert!(resp.predicted_class() < h.classes());
    }

    #[test]
    fn batches_concurrent_requests() {
        let Some(m) = manifest() else { return };
        let config = ServerConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_delay: Duration::from_millis(30),
                queue_capacity: 64,
            },
            // This test checks the batcher mechanics; keep all
            // executable sizes even where the adaptive policy would
            // prune them.
            adaptive_sizes: false,
            ..ServerConfig::default()
        };
        let server = Server::start(m, config).unwrap();
        let h = server.handle();
        let elems = h.image_elems();

        std::thread::scope(|s| {
            for t in 0..16u64 {
                let h = h.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(t);
                    let resp = h.infer(image(&mut rng, elems)).unwrap();
                    assert_eq!(resp.logits.len(), h.classes());
                });
            }
        });
        let snap = server.metrics();
        assert_eq!(snap.requests, 16);
        assert!(
            snap.mean_batch_size > 1.0,
            "dynamic batching never batched (mean={})",
            snap.mean_batch_size
        );
    }

    #[test]
    fn deterministic_outputs_across_batch_sizes() {
        let Some(m) = manifest() else { return };
        let config = ServerConfig {
            policy: BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_millis(20),
                queue_capacity: 64,
            },
            adaptive_sizes: false,
            ..ServerConfig::default()
        };
        let server = Server::start(m, config).unwrap();
        let h = server.handle();
        let mut rng = Rng::new(99);
        let img = image(&mut rng, h.image_elems());

        let solo = h.infer(img.clone()).unwrap();
        let batched = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..4u64 {
                let h2 = h.clone();
                let elems = h.image_elems();
                let img2 = if t == 0 {
                    img.clone()
                } else {
                    image(&mut Rng::new(1000 + t), elems)
                };
                handles.push(s.spawn(move || h2.infer(img2).unwrap()));
            }
            handles.remove(0).join().unwrap()
        });
        for (a, b) in solo.logits.iter().zip(batched.logits.iter()) {
            assert!((a - b).abs() < 1e-4, "solo {a} vs batched {b}");
        }
    }
}
