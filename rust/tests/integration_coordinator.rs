//! Integration: the serving coordinator end to end over real AOT
//! artifacts (skipped when artifacts are not built).

use std::time::Duration;

use cuconv::coordinator::{BatchPolicy, Server, ServerConfig};
use cuconv::runtime::Manifest;
use cuconv::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    let dir = cuconv::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(dir).unwrap())
}

fn image(rng: &mut Rng, elems: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; elems];
    rng.fill_uniform(&mut v, -1.0, 1.0);
    v
}

#[test]
fn serves_single_request() {
    let Some(m) = manifest() else { return };
    let server = Server::start(m, ServerConfig::default()).unwrap();
    let h = server.handle();
    let mut rng = Rng::new(1);
    let resp = h.infer(image(&mut rng, h.image_elems())).unwrap();
    assert_eq!(resp.logits.len(), h.classes());
    assert!(resp.total_seconds > 0.0);
    assert!(resp.batch_size >= 1);
    assert!(resp.predicted_class() < h.classes());
}

#[test]
fn rejects_wrong_image_size() {
    let Some(m) = manifest() else { return };
    let server = Server::start(m, ServerConfig::default()).unwrap();
    assert!(server.handle().infer(vec![0.0; 7]).is_err());
}

#[test]
fn batches_concurrent_requests() {
    let Some(m) = manifest() else { return };
    let config = ServerConfig {
        policy: BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_millis(30),
            queue_capacity: 64,
        },
        // This test checks the batcher mechanics; keep all executable
        // sizes even where the adaptive policy would prune them.
        adaptive_sizes: false,
        ..ServerConfig::default()
    };
    let server = Server::start(m, config).unwrap();
    let h = server.handle();
    let elems = h.image_elems();

    // Fire 16 requests concurrently; the router should form multi-image
    // batches (the AOT family has batch sizes 1,2,4,8).
    std::thread::scope(|s| {
        for t in 0..16u64 {
            let h = h.clone();
            s.spawn(move || {
                let mut rng = Rng::new(t);
                let resp = h.infer(image(&mut rng, elems)).unwrap();
                assert_eq!(resp.logits.len(), h.classes());
            });
        }
    });
    let snap = server.metrics();
    assert_eq!(snap.requests, 16);
    assert!(
        snap.mean_batch_size > 1.0,
        "dynamic batching never batched (mean={})",
        snap.mean_batch_size
    );
    assert!(snap.throughput_rps > 0.0);
}

#[test]
fn deterministic_outputs_across_batch_sizes() {
    // The same image must produce the same logits whether it is served
    // alone or inside a batch — the batcher must not mix rows up.
    let Some(m) = manifest() else { return };
    let config = ServerConfig {
        policy: BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_millis(20),
            queue_capacity: 64,
        },
        adaptive_sizes: false,
        ..ServerConfig::default()
    };
    let server = Server::start(m, config).unwrap();
    let h = server.handle();
    let mut rng = Rng::new(99);
    let img = image(&mut rng, h.image_elems());

    // Serve alone.
    let solo = h.infer(img.clone()).unwrap();

    // Serve among distinct other images, concurrently.
    let batched = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let h2 = h.clone();
            let img2 = if t == 0 {
                img.clone()
            } else {
                image(&mut Rng::new(1000 + t), elemsof(&h))
            };
            handles.push(s.spawn(move || h2.infer(img2).unwrap()));
        }
        handles.remove(0).join().unwrap()
    });
    for (a, b) in solo.logits.iter().zip(batched.logits.iter()) {
        assert!((a - b).abs() < 1e-4, "solo {a} vs batched {b}");
    }
}

fn elemsof(h: &cuconv::coordinator::ServerHandle) -> usize {
    h.image_elems()
}

#[test]
fn backpressure_rejects_when_flooded() {
    let Some(m) = manifest() else { return };
    let config = ServerConfig {
        policy: BatchPolicy {
            max_batch: 1,
            max_delay: Duration::from_millis(1),
            queue_capacity: 2,
        },
        ..ServerConfig::default()
    };
    let server = Server::start(m, config).unwrap();
    let h = server.handle();
    let elems = h.image_elems();
    let mut rng = Rng::new(3);

    // Flood with async submissions; keep receivers so accepted ones
    // complete. With capacity 2 and instant flooding, rejections are
    // expected — and the count must be reflected in the metrics.
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for _ in 0..64 {
        match h.submit(image(&mut rng, elems)) {
            Ok(rx) => accepted.push(rx),
            Err(_) => rejected += 1,
        }
    }
    for rx in accepted {
        let _ = rx.recv();
    }
    let snap = server.metrics();
    assert_eq!(snap.rejected as usize, rejected);
}

#[test]
fn shutdown_is_clean() {
    let Some(m) = manifest() else { return };
    let mut server = Server::start(m, ServerConfig::default()).unwrap();
    let h = server.handle();
    let mut rng = Rng::new(5);
    let _ = h.infer(image(&mut rng, h.image_elems())).unwrap();
    server.shutdown();
    // Further submissions fail cleanly.
    assert!(h.infer(image(&mut rng, h.image_elems())).is_err());
}
