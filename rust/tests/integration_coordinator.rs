//! Integration: the serving coordinator end to end.
//!
//! The conv-backend serving path (a convolution layer through the
//! [`Backend`](cuconv::backend::Backend) API) runs on every build; the
//! AOT-model path additionally needs the `pjrt` feature and built
//! artifacts (skipped with a note otherwise).

use std::time::Duration;

use cuconv::backend::CpuRefBackend;
use cuconv::conv::ConvSpec;
use cuconv::coordinator::{BatchPolicy, Server};
use cuconv::util::rng::Rng;

fn image(rng: &mut Rng, elems: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; elems];
    rng.fill_uniform(&mut v, -1.0, 1.0);
    v
}

/// A conv-layer server over the CPU reference backend — no artifacts.
fn conv_server(policy: BatchPolicy) -> Server {
    let spec = ConvSpec::paper(8, 1, 3, 4, 4);
    Server::start_conv(Box::new(CpuRefBackend::new()), spec, None, &[1, 2, 4, 8], policy)
        .unwrap()
}

#[test]
fn conv_server_serves_single_request() {
    let server = conv_server(BatchPolicy::default());
    let h = server.handle();
    let mut rng = Rng::new(1);
    let resp = h.infer(image(&mut rng, h.image_elems())).unwrap();
    assert_eq!(resp.logits.len(), h.classes());
    assert!(resp.total_seconds > 0.0);
    assert!(resp.batch_size >= 1);
}

#[test]
fn conv_server_rejects_wrong_image_size() {
    let server = conv_server(BatchPolicy::default());
    assert!(server.handle().infer(vec![0.0; 7]).is_err());
}

#[test]
fn conv_server_batches_concurrent_requests() {
    let policy = BatchPolicy {
        max_batch: 8,
        max_delay: Duration::from_millis(30),
        queue_capacity: 64,
    };
    let server = conv_server(policy);
    let h = server.handle();
    let elems = h.image_elems();

    // Fire 16 requests concurrently; the router should form multi-image
    // batches (plans exist for batch sizes 1,2,4,8).
    std::thread::scope(|s| {
        for t in 0..16u64 {
            let h = h.clone();
            s.spawn(move || {
                let mut rng = Rng::new(t);
                let resp = h.infer(image(&mut rng, elems)).unwrap();
                assert_eq!(resp.logits.len(), h.classes());
            });
        }
    });
    let snap = server.metrics();
    assert_eq!(snap.requests, 16);
    assert!(
        snap.mean_batch_size > 1.0,
        "dynamic batching never batched (mean={})",
        snap.mean_batch_size
    );
    assert!(snap.throughput_rps > 0.0);
}

#[test]
fn conv_server_solo_vs_batched_outputs_agree() {
    // The same pixels must produce the same conv output whether served
    // alone or inside a batch — the batcher must not mix rows up, and
    // the runner's per-size plans must agree numerically.
    let policy = BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_millis(20),
        queue_capacity: 64,
    };
    let server = conv_server(policy);
    let h = server.handle();
    let mut rng = Rng::new(99);
    let img = image(&mut rng, h.image_elems());

    let solo = h.infer(img.clone()).unwrap();

    let batched = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let h2 = h.clone();
            let elems = h.image_elems();
            let img2 =
                if t == 0 { img.clone() } else { image(&mut Rng::new(1000 + t), elems) };
            handles.push(s.spawn(move || h2.infer(img2).unwrap()));
        }
        handles.remove(0).join().unwrap()
    });
    for (a, b) in solo.logits.iter().zip(batched.logits.iter()) {
        assert!((a - b).abs() < 1e-4, "solo {a} vs batched {b}");
    }
}

#[test]
fn conv_server_backpressure_rejects_when_flooded() {
    let policy = BatchPolicy {
        max_batch: 1,
        max_delay: Duration::from_millis(1),
        queue_capacity: 2,
    };
    let server = conv_server(policy);
    let h = server.handle();
    let elems = h.image_elems();
    let mut rng = Rng::new(3);

    let mut accepted = Vec::new();
    let mut rejected = 0;
    for _ in 0..64 {
        match h.submit(image(&mut rng, elems)) {
            Ok(rx) => accepted.push(rx),
            Err(_) => rejected += 1,
        }
    }
    for rx in accepted {
        let _ = rx.recv();
    }
    let snap = server.metrics();
    assert_eq!(snap.rejected as usize, rejected);
}

#[test]
fn conv_server_shutdown_is_clean() {
    let mut server = conv_server(BatchPolicy::default());
    let h = server.handle();
    let mut rng = Rng::new(5);
    let _ = h.infer(image(&mut rng, h.image_elems())).unwrap();
    server.shutdown();
    // Further submissions fail cleanly.
    assert!(h.infer(image(&mut rng, h.image_elems())).is_err());
}

/// The AOT-model serving path (needs `--features pjrt` + artifacts).
#[cfg(feature = "pjrt")]
mod pjrt_model {
    use super::*;
    use cuconv::coordinator::ServerConfig;
    use cuconv::runtime::Manifest;

    fn manifest() -> Option<Manifest> {
        let dir = cuconv::runtime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Manifest::load(dir).unwrap())
    }

    #[test]
    fn serves_single_request() {
        let Some(m) = manifest() else { return };
        let server = Server::start(m, ServerConfig::default()).unwrap();
        let h = server.handle();
        let mut rng = Rng::new(1);
        let resp = h.infer(image(&mut rng, h.image_elems())).unwrap();
        assert_eq!(resp.logits.len(), h.classes());
        assert!(resp.total_seconds > 0.0);
        assert!(resp.predicted_class() < h.classes());
    }

    #[test]
    fn batches_concurrent_requests() {
        let Some(m) = manifest() else { return };
        let config = ServerConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_delay: Duration::from_millis(30),
                queue_capacity: 64,
            },
            // This test checks the batcher mechanics; keep all
            // executable sizes even where the adaptive policy would
            // prune them.
            adaptive_sizes: false,
            ..ServerConfig::default()
        };
        let server = Server::start(m, config).unwrap();
        let h = server.handle();
        let elems = h.image_elems();

        std::thread::scope(|s| {
            for t in 0..16u64 {
                let h = h.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(t);
                    let resp = h.infer(image(&mut rng, elems)).unwrap();
                    assert_eq!(resp.logits.len(), h.classes());
                });
            }
        });
        let snap = server.metrics();
        assert_eq!(snap.requests, 16);
        assert!(
            snap.mean_batch_size > 1.0,
            "dynamic batching never batched (mean={})",
            snap.mean_batch_size
        );
    }

    #[test]
    fn deterministic_outputs_across_batch_sizes() {
        let Some(m) = manifest() else { return };
        let config = ServerConfig {
            policy: BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_millis(20),
                queue_capacity: 64,
            },
            adaptive_sizes: false,
            ..ServerConfig::default()
        };
        let server = Server::start(m, config).unwrap();
        let h = server.handle();
        let mut rng = Rng::new(99);
        let img = image(&mut rng, h.image_elems());

        let solo = h.infer(img.clone()).unwrap();
        let batched = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..4u64 {
                let h2 = h.clone();
                let elems = h.image_elems();
                let img2 = if t == 0 {
                    img.clone()
                } else {
                    image(&mut Rng::new(1000 + t), elems)
                };
                handles.push(s.spawn(move || h2.infer(img2).unwrap()));
            }
            handles.remove(0).join().unwrap()
        });
        for (a, b) in solo.logits.iter().zip(batched.logits.iter()) {
            assert!((a - b).abs() < 1e-4, "solo {a} vs batched {b}");
        }
    }
}
