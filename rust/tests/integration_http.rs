//! Integration: the HTTP/JSON front door over real loopback sockets —
//! bit-identical inference through the full wire path, deadline and
//! rate-limit admission, endpoint smoke, and malformed-request
//! robustness.

use std::time::{Duration, Instant};

use cuconv::backend::CpuRefBackend;
use cuconv::coordinator::{BatchPolicy, PoolConfig, Server, ServerBuilder};
use cuconv::http::{
    infer_body, logits_of, wait_healthy, AppState, HttpClient, HttpConfig,
    HttpServer, RateLimit, TenantLimiter,
};
use cuconv::net::{network_graph, GraphBuilder, NetGraph, NetPlanner};
use cuconv::util::json::parse;
use cuconv::util::rng::Rng;
use cuconv::zoo::Network;

/// A small net that exercises conv/pool/linear/softmax without
/// SqueezeNet-scale compute — the workhorse for the admission tests.
fn tiny_graph() -> NetGraph {
    let mut b = GraphBuilder::new("tiny-net", 2, 10, 10);
    let c1 = b.conv_same("c1", b.input(), 6, 3);
    let p = b.max_pool("p", c1, 2, 2, 0);
    let g = b.global_avg_pool("gap", p);
    let fc = b.linear("fc", g, 7, false);
    b.softmax("sm", fc);
    b.finish()
}

struct FrontDoor {
    // Field order is drop order: the HTTP listener goes down before the
    // pool it dispatches into.
    http: HttpServer,
    server: Server,
    model: String,
    image_elems: usize,
}

impl FrontDoor {
    fn start(
        graph: &NetGraph,
        batch_sizes: &[usize],
        rate_limit: Option<RateLimit>,
        default_deadline: Option<Duration>,
        http_cfg: HttpConfig,
    ) -> FrontDoor {
        let server = ServerBuilder::net(Box::new(CpuRefBackend::new()), graph, batch_sizes)
            .policy(BatchPolicy {
                max_batch: *batch_sizes.iter().max().unwrap(),
                max_delay: Duration::from_millis(5),
                queue_capacity: 64,
            })
            .pool(PoolConfig::with_workers(1))
            .start()
            .expect("pool");
        let handle = server.handle();
        let image_elems = handle.image_elems();
        let http = HttpServer::start(
            AppState {
                handle,
                model: graph.name.clone(),
                max_batch: *batch_sizes.iter().max().unwrap(),
                limiter: TenantLimiter::new(rate_limit),
                default_deadline,
                started: Instant::now(),
            },
            http_cfg,
        )
        .expect("http server");
        wait_healthy(http.addr(), Duration::from_secs(5)).expect("healthz");
        FrontDoor { http, server, model: graph.name.clone(), image_elems }
    }

    fn client(&self) -> HttpClient {
        HttpClient::connect(self.http.addr()).expect("connect")
    }

    fn rand_image(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut img = vec![0.0f32; self.image_elems];
        rng.fill_uniform(&mut img, -1.0, 1.0);
        img
    }
}

fn class_of(body: &str) -> String {
    parse(body)
        .expect("error body is JSON")
        .get("class")
        .and_then(|c| c.as_str().map(str::to_string))
        .expect("error body has a class")
}

/// The acceptance-criteria test: SqueezeNet served over a real TCP
/// socket — JSON encode, lazy-scan admission, payload decode, dynamic
/// batching, inference, JSON response — must produce logits
/// **bit-identical** to [`NetPlan::forward_reference`] on the same
/// images. The wire format (shortest-roundtrip f32) and the serving
/// stack (replicated plans, batch grouping) are both lossless, so
/// equality here is exact, not approximate.
#[test]
fn squeezenet_over_loopback_is_bit_identical_to_reference() {
    let graph = network_graph(Network::SqueezeNet);
    let fd = FrontDoor::start(&graph, &[1, 2], None, None, HttpConfig::default());
    let img0 = fd.rand_image(40);
    let img1 = fd.rand_image(41);

    // The oracle: the allocating reference forward at batch 1.
    let p = NetPlanner::new(Box::new(CpuRefBackend::new()));
    let mut plan = p.compile(&graph, 1).expect("compile reference");
    let want0 = plan.forward_reference(p.backend(), &img0).expect("reference 0");
    let want1 = plan.forward_reference(p.backend(), &img1).expect("reference 1");

    // One batch-2 request over the socket carrying both images.
    let mut payload = img0.clone();
    payload.extend_from_slice(&img1);
    let body = infer_body(&fd.model, 2, None, Some("itest"), None, &payload);
    let mut c = fd.client();
    let (status, resp) = c.post_json("/v1/infer", &body).expect("infer");
    assert_eq!(status, 200, "infer failed: {resp}");
    let rows = logits_of(&resp).expect("logits");
    assert_eq!(rows.len(), 2);
    for (got, want) in [(&rows[0], &want0), (&rows[1], &want1)] {
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(want.iter()) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "served logit {a} != reference {b} — the wire path must be lossless"
            );
        }
    }
    let m = fd.server.metrics();
    assert_eq!(m.requests, 2, "two images served");
    assert_eq!(m.expired + m.rejected, 0);
}

/// An already-elapsed deadline is refused with 504, counted `expired`,
/// and never reaches a worker — the admission layer drops it before the
/// payload is even decoded.
#[test]
fn dead_deadline_is_504_counted_expired_before_any_worker() {
    let graph = tiny_graph();
    let fd = FrontDoor::start(&graph, &[1, 2, 4], None, None, HttpConfig::default());
    let img = fd.rand_image(7);
    let mut c = fd.client();

    let body = infer_body(&fd.model, 1, Some(0), Some("t"), None, &img);
    let (status, resp) = c.post_json("/v1/infer", &body).expect("exchange");
    assert_eq!(status, 504, "zero deadline budget must be a gateway timeout");
    assert_eq!(class_of(&resp), "expired");
    let m = fd.server.metrics();
    assert_eq!(m.expired, 1, "the drop must be counted as expired");
    assert_eq!(m.requests, 0, "no worker may ever see a dead-on-arrival request");
    assert_eq!(m.rejected, 0, "expired is its own class, not a rejection");

    // A generous deadline on the same connection still completes.
    let body = infer_body(&fd.model, 1, Some(30_000), Some("t"), None, &img);
    let (status, _) = c.post_json("/v1/infer", &body).expect("exchange");
    assert_eq!(status, 200);
    assert_eq!(fd.server.metrics().requests, 1);
}

/// Per-tenant token buckets: an exhausted tenant gets 429 (`rejected`
/// class) while other tenants sail through, and the refused request
/// costs the pool nothing.
#[test]
fn rate_limited_tenant_gets_429_others_unaffected() {
    let graph = tiny_graph();
    // A bucket of exactly one token that refills slower than the test
    // runs: the second request from the same tenant must be refused.
    let limit = RateLimit::new(0.001, 1.0).unwrap();
    let fd =
        FrontDoor::start(&graph, &[1, 2], Some(limit), None, HttpConfig::default());
    let img = fd.rand_image(8);
    let mut c = fd.client();

    let body_a = infer_body(&fd.model, 1, None, Some("team-a"), None, &img);
    let (status, _) = c.post_json("/v1/infer", &body_a).expect("first");
    assert_eq!(status, 200, "a fresh tenant's first request passes");
    let (status, resp) = c.post_json("/v1/infer", &body_a).expect("second");
    assert_eq!(status, 429, "the bucket is empty");
    assert_eq!(class_of(&resp), "rejected");

    let body_b = infer_body(&fd.model, 1, None, Some("team-b"), None, &img);
    let (status, _) = c.post_json("/v1/infer", &body_b).expect("other tenant");
    assert_eq!(status, 200, "tenant isolation: team-b has its own bucket");

    let m = fd.server.metrics();
    assert_eq!(m.requests, 2, "only admitted requests reach the pool");
    assert_eq!(
        m.rejected, 0,
        "a rate-limit refusal happens above the dispatcher; the pool never \
         counts it"
    );
}

/// The observability endpoints: /healthz, /v1/models, /metrics (with
/// SLO buckets), plus 404/405 for unknown routes and wrong methods.
#[test]
fn healthz_models_and_metrics_answer_over_one_connection() {
    let graph = tiny_graph();
    let fd = FrontDoor::start(&graph, &[1, 2], None, None, HttpConfig::default());
    let mut c = fd.client();

    let (status, body) = c.get("/healthz").expect("healthz");
    assert_eq!(status, 200);
    let v = parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok");

    let (status, body) = c.get("/v1/models").expect("models");
    assert_eq!(status, 200);
    let v = parse(&body).unwrap();
    let models = v.get("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 1);
    assert_eq!(models[0].get("name").unwrap().as_str().unwrap(), fd.model);
    assert_eq!(
        models[0].get("input_elems").unwrap().as_usize().unwrap(),
        fd.image_elems
    );

    // Serve one request, then read it back out of /metrics.
    let img = fd.rand_image(9);
    let body = infer_body(&fd.model, 1, None, None, None, &img);
    let (status, _) = c.post_json("/v1/infer", &body).expect("infer");
    assert_eq!(status, 200);
    let (status, body) = c.get("/metrics").expect("metrics");
    assert_eq!(status, 200);
    let v = parse(&body).unwrap();
    assert_eq!(v.get("requests").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("expired").unwrap().as_usize().unwrap(), 0);
    let slo = v.get("slo").unwrap().as_arr().unwrap();
    assert_eq!(
        slo.len(),
        cuconv::coordinator::SLO_BOUNDS_SECONDS.len(),
        "every SLO bound must be rendered"
    );
    let counts: Vec<usize> =
        slo.iter().map(|b| b.get("count").unwrap().as_usize().unwrap()).collect();
    assert!(counts.windows(2).all(|w| w[0] <= w[1]), "cumulative: {counts:?}");
    assert_eq!(*counts.last().unwrap(), 1, "the served request is within 250ms");

    let (status, resp) = c.get("/nope").expect("404");
    assert_eq!(status, 404);
    assert_eq!(class_of(&resp), "invalid");
    let (status, _) = c.post_json("/healthz", "{}").expect("405");
    assert_eq!(status, 405);
}

/// Malformed requests are answered 400/404 with a JSON error body — and
/// the connection and server both survive to serve a valid request
/// afterwards.
#[test]
fn malformed_requests_get_400s_and_never_wedge_the_server() {
    let graph = tiny_graph();
    let fd = FrontDoor::start(&graph, &[1, 2], None, None, HttpConfig::default());
    let img = fd.rand_image(10);
    let mut c = fd.client();

    let cases: Vec<(String, u16)> = vec![
        // Garbage and truncated JSON.
        ("THIS IS NOT JSON".to_string(), 400),
        (r#"{"model": "tiny-net", "payload": [1, 2"#.to_string(), 400),
        // Missing required fields.
        (r#"{"payload": [1.0]}"#.to_string(), 400),
        (format!(r#"{{"model": "{}"}}"#, fd.model), 400),
        // Unknown model routes 404.
        (infer_body("no-such-model", 1, None, None, None, &img), 404),
        // Wrong payload size, zero batch, over-max batch.
        (infer_body(&fd.model, 1, None, None, None, &img[..img.len() - 1]), 400),
        (infer_body(&fd.model, 0, None, None, None, &img), 400),
        (format!(
            r#"{{"model": "{}", "batch": 99, "payload": [1.0]}}"#,
            fd.model
        ), 400),
        // Non-numeric payload element.
        (format!(
            r#"{{"model": "{}", "payload": [1.0, "x"]}}"#,
            fd.model
        ), 400),
    ];
    for (body, want) in cases {
        let (status, resp) = c.post_json("/v1/infer", &body).expect("exchange");
        assert_eq!(status, want, "body {body:.60} → {resp}");
        assert!(parse(&resp).is_ok(), "error bodies are JSON: {resp}");
    }

    // The same keep-alive connection still serves a valid request.
    let body = infer_body(&fd.model, 1, None, None, None, &img);
    let (status, _) = c.post_json("/v1/infer", &body).expect("valid after garbage");
    assert_eq!(status, 200);
    let m = fd.server.metrics();
    assert_eq!(m.requests, 1, "only the valid request reached the pool");
}

/// A `"priority": "batch"` tag rides the wire into the dispatcher and
/// lands in the Batch accounting class, visible in /metrics per_class;
/// an unknown tag is a 400 before any admission cost.
#[test]
fn priority_tag_roundtrips_into_per_class_metrics() {
    let graph = tiny_graph();
    let fd = FrontDoor::start(&graph, &[1, 2], None, None, HttpConfig::default());
    let img = fd.rand_image(12);
    let mut c = fd.client();

    let body = infer_body(
        &fd.model,
        1,
        None,
        None,
        Some(cuconv::coordinator::Priority::Batch),
        &img,
    );
    let (status, _) = c.post_json("/v1/infer", &body).expect("batch infer");
    assert_eq!(status, 200);

    let (status, body) = c.get("/metrics").expect("metrics");
    assert_eq!(status, 200);
    let v = parse(&body).unwrap();
    let classes = v.get("per_class").unwrap().as_arr().unwrap();
    let completed_of = |name: &str| {
        classes
            .iter()
            .find(|r| r.get("priority").unwrap().as_str().unwrap() == name)
            .unwrap_or_else(|| panic!("missing class row {name}"))
            .get("completed")
            .unwrap()
            .as_usize()
            .unwrap()
    };
    assert_eq!(completed_of("batch"), 1, "the request must count in its own class");
    assert_eq!(completed_of("interactive"), 0);

    // An unknown priority is a shape error, refused before admission.
    let bad = format!(
        r#"{{"model": "{}", "priority": "urgent", "payload": [1.0]}}"#,
        fd.model
    );
    let (status, resp) = c.post_json("/v1/infer", &bad).expect("bad priority");
    assert_eq!(status, 400);
    assert_eq!(class_of(&resp), "invalid");
    assert!(resp.contains("priority"), "the error must name the field: {resp}");
    assert_eq!(fd.server.metrics().requests, 1, "the bad request never dispatched");
}

/// Honest health: once a worker is dead (here: an unsupervised pool
/// whose runner panics), `GET /healthz` must stop saying 200 "ok" and
/// answer 503 "degraded" with the live-worker count.
#[test]
fn healthz_degrades_to_503_when_a_worker_dies() {
    use anyhow::Result;
    use cuconv::coordinator::{BatchOutput, BatchRunner};

    struct Exploder;
    impl BatchRunner for Exploder {
        fn batch_sizes(&self) -> Vec<usize> {
            vec![1]
        }
        fn item_in_elems(&self) -> usize {
            2
        }
        fn item_out_elems(&self) -> usize {
            2
        }
        fn run(&mut self, _batch: usize, _input: Vec<f32>) -> Result<BatchOutput> {
            panic!("exploder: always panics");
        }
        fn replicate(&self) -> Result<Box<dyn BatchRunner>> {
            Ok(Box::new(Exploder))
        }
    }

    let server = ServerBuilder::runner(Box::new(Exploder))
        .policy(BatchPolicy {
            max_batch: 1,
            max_delay: Duration::from_millis(1),
            queue_capacity: 4,
        })
        .pool(PoolConfig { workers: 2, supervise: false, ..PoolConfig::default() })
        .start()
        .expect("pool");
    let handle = server.handle();
    let http = HttpServer::start(
        AppState {
            handle: handle.clone(),
            model: "exploding".to_string(),
            max_batch: 1,
            limiter: TenantLimiter::new(None),
            default_deadline: None,
            started: Instant::now(),
        },
        HttpConfig::default(),
    )
    .expect("http server");
    wait_healthy(http.addr(), Duration::from_secs(5)).expect("healthy while intact");

    let mut c = HttpClient::connect(http.addr()).expect("connect");
    let (status, body) = c.get("/healthz").expect("healthz");
    assert_eq!(status, 200, "all workers live: {body}");

    // Kill one worker through the dispatcher; the panic is answered as
    // an error, and health must degrade immediately after.
    assert!(handle.infer(vec![0.0; 2]).is_err(), "the panicking worker errors");
    let (status, body) = c.get("/healthz").expect("healthz after panic");
    assert_eq!(status, 503, "a dead worker must fail health: {body}");
    let v = parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "degraded");
    assert_eq!(v.get("workers").unwrap().as_usize().unwrap(), 2);
    assert_eq!(v.get("live_workers").unwrap().as_usize().unwrap(), 1);
}

/// Request correlation ids over the real socket: a client-supplied
/// `X-Request-Id` is echoed on both success and error responses, and a
/// request without one gets a server-minted `req-<hex>` id — no
/// response leaves the front door unlabelled.
#[test]
fn request_ids_echo_on_success_and_error_and_are_minted_when_absent() {
    let graph = tiny_graph();
    let fd = FrontDoor::start(&graph, &[1, 2], None, None, HttpConfig::default());
    let img = fd.rand_image(13);
    let mut c = fd.client();

    // Client-chosen id, happy path.
    let body = infer_body(&fd.model, 1, None, None, None, &img);
    let (status, _, echoed) = c
        .post_json_traced("/v1/infer", &body, Some("trace-42"))
        .expect("infer");
    assert_eq!(status, 200);
    assert_eq!(echoed.as_deref(), Some("trace-42"), "200s must echo the id");

    // Same id on an error response (unparseable body → 400).
    let (status, _, echoed) = c
        .post_json_traced("/v1/infer", "NOT JSON", Some("trace-43"))
        .expect("bad infer");
    assert_eq!(status, 400);
    assert_eq!(
        echoed.as_deref(),
        Some("trace-43"),
        "error responses must carry the id too"
    );

    // No id sent → the server mints one.
    let (status, _, minted) =
        c.post_json_traced("/v1/infer", &body, None).expect("infer sans id");
    assert_eq!(status, 200);
    let minted = minted.expect("server must mint an id when the client sends none");
    assert!(minted.starts_with("req-"), "minted id shape: {minted}");
}

/// Oversized bodies are refused with 413 before any buffering, and the
/// server stays healthy for new connections.
#[test]
fn oversized_body_is_413_and_server_survives() {
    let graph = tiny_graph();
    let fd = FrontDoor::start(
        &graph,
        &[1],
        None,
        None,
        HttpConfig { max_body_bytes: 1024, ..HttpConfig::default() },
    );
    let img = fd.rand_image(11);
    let body = infer_body(&fd.model, 1, None, None, None, &img); // > 1 KiB of text
    assert!(body.len() > 1024, "test body must exceed the configured cap");
    let mut c = fd.client();
    let (status, resp) = c.post_json("/v1/infer", &body).expect("exchange");
    assert_eq!(status, 413);
    assert_eq!(class_of(&resp), "invalid");
    // That connection is closed (framing was unrecoverable); a fresh
    // one works — with a body under the cap.
    let mut c2 = fd.client();
    let (status, _) = c2.get("/healthz").expect("fresh connection");
    assert_eq!(status, 200);
}

/// A non-finite pixel is refused with 400 class "invalid" *naming the
/// offending element*, before any worker sees it. JSON cannot spell
/// `NaN`, so the wire-level vehicle is an overflowing literal (`1e999`
/// parses to +Inf) — the NaN case itself is covered by the router's
/// unit test on the same check.
#[test]
fn nonfinite_payload_is_400_invalid_and_never_dispatched() {
    let graph = tiny_graph();
    let fd = FrontDoor::start(&graph, &[1, 2], None, None, HttpConfig::default());
    let mut c = fd.client();

    let mut parts = vec!["0.5".to_string(); fd.image_elems];
    parts[3] = "1e999".to_string(); // +Inf after parsing
    let body = format!(
        r#"{{"model": "{}", "payload": [{}]}}"#,
        fd.model,
        parts.join(",")
    );
    let (status, resp) = c.post_json("/v1/infer", &body).expect("exchange");
    assert_eq!(status, 400, "an infinite pixel must be refused: {resp}");
    assert_eq!(class_of(&resp), "invalid");
    assert!(
        resp.contains("finite") && resp.contains("element 3"),
        "the error must say which element is not finite: {resp}"
    );

    parts[3] = "-1e999".to_string(); // -Inf too
    let body = format!(
        r#"{{"model": "{}", "payload": [{}]}}"#,
        fd.model,
        parts.join(",")
    );
    let (status, resp) = c.post_json("/v1/infer", &body).expect("exchange");
    assert_eq!(status, 400);
    assert_eq!(class_of(&resp), "invalid");

    assert_eq!(
        fd.server.metrics().requests,
        0,
        "a non-finite payload must never reach a worker"
    );

    // Finite payloads on the same connection still serve.
    let img = fd.rand_image(14);
    let ok = infer_body(&fd.model, 1, None, None, None, &img);
    let (status, _) = c.post_json("/v1/infer", &ok).expect("valid after garbage");
    assert_eq!(status, 200);
}

/// A 429 refusal carries `Retry-After` advice derived from the bucket's
/// actual refill deficit — parseable by the client into whole seconds —
/// and the advised wait is at least one second (clamped, never zero).
#[test]
fn rate_limit_429_carries_retry_after_advice() {
    let graph = tiny_graph();
    // One token, refilling at 0.5 rps: the second request must wait
    // ~2 s for a whole token, so the advice is ceil(2) = 2.
    let limit = RateLimit::new(0.5, 1.0).unwrap();
    let fd =
        FrontDoor::start(&graph, &[1, 2], Some(limit), None, HttpConfig::default());
    let img = fd.rand_image(15);
    let mut c = fd.client();

    let body = infer_body(&fd.model, 1, None, Some("team-a"), None, &img);
    let (status, _, advised) =
        c.post_json_advised("/v1/infer", &body).expect("first");
    assert_eq!(status, 200);
    assert_eq!(advised, None, "success responses carry no Retry-After");

    let (status, resp, advised) =
        c.post_json_advised("/v1/infer", &body).expect("second");
    assert_eq!(status, 429, "the bucket is empty: {resp}");
    assert_eq!(class_of(&resp), "rejected");
    let advised = advised.expect("429 must carry Retry-After advice");
    assert!(
        (1..=3).contains(&advised),
        "advice must track the ~2 s refill deficit, got {advised}"
    );
}

/// The watchdog counters and the draining flag ride both observability
/// endpoints, and a drained pool reports `draining` as a *healthy*
/// (non-503) state — a balancer reads the flag, a status-only checker
/// keeps seeing 200.
#[test]
fn healthz_and_metrics_surface_watchdog_counters_and_draining() {
    let graph = tiny_graph();
    let mut fd = FrontDoor::start(&graph, &[1, 2], None, None, HttpConfig::default());
    let mut c = fd.client();

    let (status, body) = c.get("/healthz").expect("healthz");
    assert_eq!(status, 200);
    let v = parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(v.get("draining").unwrap().as_bool().unwrap(), false);
    assert_eq!(v.get("stalled_evictions").unwrap().as_usize().unwrap(), 0);
    assert_eq!(v.get("fenced_discards").unwrap().as_usize().unwrap(), 0);

    let (status, body) = c.get("/metrics").expect("metrics");
    assert_eq!(status, 200);
    let v = parse(&body).unwrap();
    assert_eq!(v.get("stalled_evictions").unwrap().as_usize().unwrap(), 0);
    assert_eq!(v.get("fenced_discards").unwrap().as_usize().unwrap(), 0);
    assert_eq!(v.get("draining").unwrap().as_bool().unwrap(), false);

    // Drain the pool (idle: completes immediately); health must flip to
    // "draining" while staying 200 — draining is not degradation.
    fd.server.shutdown();
    let (status, body) = c.get("/healthz").expect("healthz while draining");
    assert_eq!(status, 200, "draining is a healthy state, not an error: {body}");
    let v = parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "draining");
    assert_eq!(v.get("draining").unwrap().as_bool().unwrap(), true);
}
