//! Integration tests of the whole-network forward engine: the five zoo
//! networks run input-to-logits on the CPU reference backend, and the
//! steady-state forward path is allocation-flat (PR 2's per-conv
//! workspace test at network scope).

use cuconv::backend::CpuRefBackend;
use cuconv::net::{
    input_hw, network_graph, FeatShape, GraphBuilder, NetPlanner, CLASSES,
};
use cuconv::util::rng::Rng;
use cuconv::zoo::Network;

fn planner() -> NetPlanner {
    NetPlanner::new(Box::new(CpuRefBackend::new()))
}

/// Shape propagation: every zoo network's graph type-checks from its
/// 224×224 (227×227 AlexNet) input down to its 1000-class logits.
#[test]
fn every_network_graph_type_checks_input_to_logits() {
    for net in Network::ALL {
        let graph = network_graph(net);
        let shapes = graph
            .infer_shapes()
            .unwrap_or_else(|e| panic!("{}: {e:#}", graph.name));
        let hw = input_hw(net);
        assert_eq!(graph.input_shape(), FeatShape::new(3, hw, hw), "{}", graph.name);
        assert_eq!(
            shapes[graph.output_id()],
            FeatShape::new(CLASSES, 1, 1),
            "{} must end at {CLASSES} logits",
            graph.name
        );
    }
}

/// The acceptance run: all five networks execute a full forward pass on
/// `CpuRefBackend` with correct output shapes and well-formed
/// probabilities. (Real compute — VGG19 alone is ~20 GFLOP — which is
/// why the test profiles build the library optimized.)
#[test]
fn all_five_networks_run_a_full_forward_pass() {
    for net in Network::ALL {
        let graph = network_graph(net);
        let p = planner();
        let mut plan = p
            .compile(&graph, 1)
            .unwrap_or_else(|e| panic!("{}: compile failed: {e:#}", graph.name));
        let hw = input_hw(net);
        assert_eq!(plan.input_elems(), 3 * hw * hw, "{}", graph.name);
        assert_eq!(plan.output_elems(), CLASSES, "{}", graph.name);

        let mut rng = Rng::new(0x5EED ^ hw as u64);
        let mut image = vec![0.0f32; plan.input_elems()];
        rng.fill_uniform(&mut image, -1.0, 1.0);
        let probs = plan.forward(p.backend(), &image).expect("forward");

        assert_eq!(probs.len(), CLASSES, "{}", graph.name);
        assert!(
            probs.iter().all(|v| v.is_finite() && *v >= 0.0),
            "{}: non-finite/negative probabilities (weight-scale blowup?)",
            graph.name
        );
        let sum: f32 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "{}: softmax sums to {sum}", graph.name);
        // Not a degenerate (exactly uniform) distribution — a dead
        // network (all-zero logits) would produce max == 1/CLASSES.
        // Seeded weights discriminate only weakly after global
        // pooling, so the margin is small by design.
        let max = probs.iter().copied().fold(0.0f32, f32::max);
        assert!(max > 1.02 / CLASSES as f32, "{}: flat output (max {max})", graph.name);
        // Every conv node got an algorithm plan.
        assert!(!plan.conv_algorithms().is_empty(), "{}", graph.name);
    }
}

/// Steady-state zero-allocation: over ≥100 forwards the arena capacity,
/// workspace capacity and workspace high-water stay exactly flat, and
/// dirty buffer reuse never changes the output. Uses a small synthetic
/// graph that exercises every operator (conv with epilogue, both pools,
/// concat, residual, linear, softmax) so 100 iterations stay fast.
#[test]
fn arena_and_workspace_are_flat_over_100_forwards() {
    let mut b = GraphBuilder::new("steady", 3, 16, 16);
    let stem = b.conv("stem", b.input(), 8, 3, 2, 1); // 16 -> 8
    let br1 = b.conv_same("br1", stem, 8, 1);
    let br2 = b.conv_same("br2", stem, 8, 3);
    let cat = b.concat("cat", vec![br1, br2]); // 16ch
    let mix = b.conv_linear("mix", cat, 8, 1, 1, 0);
    let res = b.residual_add("res", mix, stem, true);
    let pool = b.max_pool("pool", res, 2, 2, 0); // 8 -> 4
    let gap = b.global_avg_pool("gap", pool);
    let fc = b.linear("fc", gap, 10, false);
    b.softmax("softmax", fc);
    let graph = b.finish();

    let p = planner();
    let mut plan = p.compile(&graph, 2).unwrap();
    let mut rng = Rng::new(77);
    let mut image = vec![0.0f32; plan.input_elems()];
    rng.fill_uniform(&mut image, -1.0, 1.0);

    // Warm up once, then record the high-water marks.
    let first = plan.forward(p.backend(), &image).unwrap();
    let arena = plan.arena_capacity_bytes();
    let planned = plan.planned_arena_bytes();
    let ws_cap = plan.workspace().capacity_bytes();
    let ws_high = plan.workspace().high_water_bytes();
    assert!(arena > 0);
    assert!(arena >= planned, "arena below its own plan");
    assert!(ws_cap >= plan.max_conv_workspace_bytes());

    for i in 0..100 {
        let out = plan.forward(p.backend(), &image).unwrap();
        assert_eq!(out, first, "forward {i} diverged (dirty-buffer reuse bug)");
        assert_eq!(plan.arena_capacity_bytes(), arena, "arena grew at forward {i}");
        assert_eq!(
            plan.workspace().capacity_bytes(),
            ws_cap,
            "workspace grew at forward {i}"
        );
        assert_eq!(
            plan.workspace().high_water_bytes(),
            ws_high,
            "workspace high-water moved at forward {i}"
        );
    }
}

/// The arena plan is far smaller than one-buffer-per-node: liveness
/// actually reuses memory on a real network graph, and the arena-backed
/// execution matches a fresh-buffer-per-node reference bit for bit.
#[test]
fn arena_reuses_memory_and_preserves_numerics_on_a_real_network() {
    // SqueezeNet: the smallest zoo network, with real branch structure.
    let graph = network_graph(Network::SqueezeNet);
    let p = planner();
    let mut plan = p.compile(&graph, 1).unwrap();

    let shapes = graph.infer_shapes().unwrap();
    let naive_bytes: usize = shapes.iter().map(|s| s.elems() * 4).sum();
    assert!(
        plan.arena_capacity_bytes() * 3 < naive_bytes,
        "arena {} B vs one-buffer-per-node {} B: liveness is not reusing",
        plan.arena_capacity_bytes(),
        naive_bytes
    );
    // Measured against the plan's own (possibly layout-lowered) graph:
    // inserted converts add nodes, and liveness must still fold them
    // into a handful of reused slots.
    assert!(
        plan.slot_count() < plan.graph().len() / 4,
        "slots: {} of {} nodes",
        plan.slot_count(),
        plan.graph().len()
    );

    let mut rng = Rng::new(123);
    let mut image = vec![0.0f32; plan.input_elems()];
    rng.fill_uniform(&mut image, -1.0, 1.0);
    let want = plan.forward_reference(p.backend(), &image).unwrap();
    let _ = plan.forward(p.backend(), &image).unwrap(); // dirty the arena
    let got = plan.forward(p.backend(), &image).unwrap();
    assert_eq!(got, want, "arena execution diverged from the reference");
}

/// Batched whole-network forwards through `compile_for_sizes` match the
/// same items run one by one — the property the serving batcher relies
/// on (one pinned algorithm per conv node across batch sizes).
#[test]
fn network_forward_is_batch_grouping_invariant() {
    let graph = network_graph(Network::SqueezeNet);
    let p = planner();
    let mut plans = p.compile_for_sizes(&graph, &[1, 2]).unwrap();
    let item = plans[0].1.input_elems();
    let mut rng = Rng::new(9);
    let mut batch = vec![0.0f32; 2 * item];
    rng.fill_uniform(&mut batch, -1.0, 1.0);
    let batched = {
        let (_, plan2) = &mut plans[1];
        plan2.forward(p.backend(), &batch).unwrap()
    };
    let (_, plan1) = &mut plans[0];
    for i in 0..2 {
        let single = plan1.forward(p.backend(), &batch[i * item..(i + 1) * item]).unwrap();
        assert_eq!(
            single,
            batched[i * CLASSES..(i + 1) * CLASSES].to_vec(),
            "item {i} depends on batch grouping"
        );
    }
}
