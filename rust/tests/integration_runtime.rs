//! Integration: AOT artifacts → PJRT → numerics vs the Rust oracle.
//!
//! These tests require the `pjrt` cargo feature (the whole file is
//! compiled out otherwise) and `make artifacts` to have run; they are
//! skipped (with a note) when the artifact directory is missing so
//! `cargo test` stays runnable on a fresh checkout.

#![cfg(feature = "pjrt")]

use cuconv::backend::{Backend, ConvDescriptor, PjrtBackend, Workspace};
use cuconv::cpuref::naive::conv_naive;
use cuconv::runtime::{spawn_executor, Engine, Manifest};
use cuconv::tensor::Tensor;
use cuconv::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = cuconv::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn sanity_config_all_algorithms_match_rust_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::from_dir(&dir).unwrap();
    let artifacts = engine.manifest().convs_for_label("8-2-3-16-32");
    assert!(!artifacts.is_empty(), "sanity config missing from manifest");
    let artifacts: Vec<_> = artifacts.into_iter().cloned().collect();

    let spec = artifacts[0].spec;
    let mut rng = Rng::new(0xF00D);
    let input = Tensor::random(spec.n, spec.c, spec.h, spec.w, &mut rng, -1.0, 1.0);
    let filters = Tensor::random(spec.m, spec.c, spec.kh, spec.kw, &mut rng, -1.0, 1.0);
    let want = conv_naive(&spec, &input, &filters);

    let mut tested = 0;
    for artifact in &artifacts {
        let (got, timing) = engine.run_conv(artifact, &input, &filters).unwrap();
        let err = got.rel_l2_error(&want);
        assert!(
            err < 5e-4,
            "algo {} disagrees with rust oracle: rel_l2={err}",
            artifact.algo
        );
        assert!(timing.exec_seconds > 0.0);
        tested += 1;
    }
    // cuconv, direct, 3 GEMM variants, winograd, fft, reference.
    assert!(tested >= 8, "expected >=8 algorithms, got {tested}");
}

#[test]
fn one_by_one_artifact_matches_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::from_dir(&dir).unwrap();
    let Some(artifact) = engine.manifest().find_conv("conv_7-1-1-32-832_cuconv").cloned()
    else {
        eprintln!("headline artifact not built; skipping");
        return;
    };
    let spec = artifact.spec;
    let mut rng = Rng::new(0xBEEF);
    let input = Tensor::random(spec.n, spec.c, spec.h, spec.w, &mut rng, -1.0, 1.0);
    let filters = Tensor::random(spec.m, spec.c, spec.kh, spec.kw, &mut rng, -1.0, 1.0);
    let want = conv_naive(&spec, &input, &filters);
    let (got, _) = engine.run_conv(&artifact, &input, &filters).unwrap();
    assert!(got.rel_l2_error(&want) < 5e-4);
}

#[test]
fn executable_cache_compiles_once() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::from_dir(&dir).unwrap();
    let artifact = engine
        .manifest()
        .find_conv("conv_8-2-3-16-32_reference")
        .cloned()
        .expect("sanity reference artifact");
    let spec = artifact.spec;
    let mut rng = Rng::new(7);
    let input = Tensor::random(spec.n, spec.c, spec.h, spec.w, &mut rng, -1.0, 1.0);
    let filters = Tensor::random(spec.m, spec.c, spec.kh, spec.kw, &mut rng, -1.0, 1.0);
    engine.run_conv(&artifact, &input, &filters).unwrap();
    assert_eq!(engine.compile_count(), 1);
    engine.run_conv(&artifact, &input, &filters).unwrap();
    engine.run_conv(&artifact, &input, &filters).unwrap();
    assert_eq!(engine.compile_count(), 1, "cache must prevent recompiles");
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::from_dir(&dir).unwrap();
    let artifact = engine
        .manifest()
        .find_conv("conv_8-2-3-16-32_reference")
        .cloned()
        .expect("sanity reference artifact");
    let bad_input = Tensor::zeros(1, 1, 8, 8);
    let filters = Tensor::zeros(
        artifact.spec.m,
        artifact.spec.c,
        artifact.spec.kh,
        artifact.spec.kw,
    );
    assert!(engine.run_conv(&artifact, &bad_input, &filters).is_err());
}

#[test]
fn model_artifacts_validate_against_sample_io() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::from_dir(&dir).unwrap();
    let models: Vec<String> =
        engine.manifest().models.iter().map(|m| m.name.clone()).collect();
    assert!(!models.is_empty(), "no model artifacts");
    for name in models {
        let err = engine.validate_model(&name).unwrap();
        // Sample outputs were computed with the reference algorithm; the
        // executable runs the Pallas cuconv kernels — agreement here
        // proves the full AOT chain end to end.
        assert!(err < 5e-4, "model {name} max abs err {err}");
    }
}

#[test]
fn pjrt_backend_plan_reuse_does_not_recompile() {
    let Some(dir) = artifacts_dir() else { return };
    let backend = PjrtBackend::from_dir(&dir).unwrap();
    let Some(artifact) = backend.manifest().find_conv("conv_8-2-3-16-32_cuconv").cloned()
    else {
        eprintln!("sanity cuconv artifact missing; skipping");
        return;
    };
    let spec = artifact.spec;
    let algo = cuconv::algo::Algorithm::CuConv;
    assert!(backend.capabilities(&spec, algo).is_supported());
    let desc = ConvDescriptor::new(spec).unwrap();
    // Planning compiles (once, at plan time) ...
    let plan = backend.plan(&desc, algo).unwrap();
    let compiles_after_plan = backend.compile_count().unwrap();
    assert!(compiles_after_plan >= 1);
    // ... and reusing the plan never recompiles.
    let mut rng = Rng::new(0x9A7);
    let input = Tensor::random(spec.n, spec.c, spec.h, spec.w, &mut rng, -1.0, 1.0);
    let filters = Tensor::random(spec.m, spec.c, spec.kh, spec.kw, &mut rng, -1.0, 1.0);
    let mut ws = Workspace::new();
    let want = conv_naive(&spec, &input, &filters);
    for _ in 0..3 {
        let got = backend.execute(&plan, &input, &filters, &mut ws).unwrap();
        assert!(got.rel_l2_error(&want) < 5e-4);
    }
    assert_eq!(
        backend.compile_count().unwrap(),
        compiles_after_plan,
        "plan reuse must keep compile_count flat"
    );
}

#[test]
fn executor_thread_roundtrip_and_concurrency() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let model = manifest.model_family("minisqueezenet").first().map(|m| m.name.clone());
    let (_guard, handle) = spawn_executor(manifest).unwrap();

    // Warmup compiles through the handle.
    if let Some(model_name) = model {
        handle.warmup(&[model_name.clone()]).unwrap();
        // Hammer it from several threads: the executor serializes safely.
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = handle.clone();
                let name = model_name.clone();
                s.spawn(move || {
                    let err = h.validate_model(&name).unwrap();
                    assert!(err < 5e-4, "thread {t}: err {err}");
                });
            }
        });
    }

    // Unknown artifact errors cleanly rather than wedging the thread.
    assert!(handle.run_model("nope", vec![0.0; 4]).is_err());
    let x = Tensor::zeros(1, 1, 1, 1);
    let w = Tensor::zeros(1, 1, 1, 1);
    assert!(handle.run_conv("nope", x, w).is_err());
}
