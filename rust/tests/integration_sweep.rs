//! The full 616-case evaluation sweep through the analytical model,
//! checked against the paper's §4.1 aggregate claims.

use cuconv::algo::Algorithm;
use cuconv::conv::FilterSize;
use cuconv::gpumodel::{self, paper::claims};
use cuconv::util::stats::geomean;
use cuconv::zoo;

struct SweepCase {
    filter: FilterSize,
    batch: usize,
    label: String,
    speedup: f64,
}

fn run_sweep() -> Vec<SweepCase> {
    let mut out = Vec::new();
    for (entry, batch) in zoo::all_cases() {
        let spec = entry.spec.with_batch(batch);
        if let Some(speedup) = gpumodel::speedup(&spec) {
            out.push(SweepCase {
                filter: spec.filter_size(),
                batch,
                label: spec.fig_label(),
                speedup,
            });
        }
    }
    out
}

#[test]
fn sweep_covers_the_full_case_set() {
    // A handful of large-batch, large-input cases exceed the paper's
    // 1 GB workspace cap for cuConv's own stage-1 temporary (§4 notes
    // the cap affects ~4% of algorithm/config cases); every other case
    // must produce a speedup.
    let cases = run_sweep();
    let total = zoo::all_cases().len();
    assert_eq!(total, 88 * 7);
    assert!(
        cases.len() >= 550,
        "only {} of {total} cases produced speedups",
        cases.len()
    );
    let missing = total - cases.len();
    assert!(missing <= total / 10, "{missing} cases missing");
    // Every missing case must be a genuine workspace exclusion.
    for (entry, batch) in zoo::all_cases() {
        let spec = entry.spec.with_batch(batch);
        if gpumodel::speedup(&spec).is_none() {
            assert!(
                spec.cuconv_temp_bytes() > cuconv::algo::WORKSPACE_CAP_BYTES,
                "{} batch {batch} missing without workspace reason",
                spec.fig_label()
            );
        }
    }
}

#[test]
fn max_speedup_is_batch1_1x1_in_paper_range() {
    // Paper: max 2.29x at 7-32-832 (1x1, batch 1).
    let cases = run_sweep();
    let best = cases
        .iter()
        .max_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap())
        .unwrap();
    assert_eq!(best.batch, 1, "max speedup at batch {}", best.batch);
    assert!(
        best.speedup > 1.5 && best.speedup < 4.0,
        "max modeled speedup {:.2} (paper {})",
        best.speedup,
        claims::MAX_SPEEDUP_1X1_B1
    );
    // The winner must be a small-input config (the 7x7 GoogleNet region).
    assert!(best.label.starts_with('7'), "max at {}", best.label);
}

#[test]
fn batch1_1x1_average_speedup_in_paper_range() {
    // Paper: 1.23x average for 1x1 at batch 1.
    let cases = run_sweep();
    let b1: Vec<f64> = cases
        .iter()
        .filter(|c| c.batch == 1 && c.filter == FilterSize::F1x1)
        .map(|c| c.speedup)
        .collect();
    assert!(!b1.is_empty());
    let avg = geomean(&b1);
    assert!(
        avg > 0.8 && avg < 2.0,
        "1x1 batch-1 geomean speedup {avg:.2} (paper avg {})",
        claims::AVG_SPEEDUP_1X1_B1
    );
}

#[test]
fn wins_concentrate_at_batch_one() {
    // Paper: cuConv wins 8.31% of configs, "almost all … batch size of 1".
    let cases = run_sweep();
    let wins: Vec<&SweepCase> = cases.iter().filter(|c| c.speedup > 1.0).collect();
    let frac = wins.len() as f64 / cases.len() as f64;
    assert!(
        frac > 0.02 && frac < 0.30,
        "win fraction {frac:.3} (paper {})",
        claims::WIN_FRACTION
    );
    let b1_wins = wins.iter().filter(|c| c.batch == 1).count();
    assert!(
        b1_wins * 2 > wins.len(),
        "only {b1_wins}/{} wins at batch 1",
        wins.len()
    );
    // Average speedup across wins (paper: 1.46x).
    let avg_win = geomean(&wins.iter().map(|c| c.speedup).collect::<Vec<_>>());
    assert!(
        avg_win > 1.1 && avg_win < 2.5,
        "avg winning speedup {avg_win:.2} (paper {})",
        claims::AVG_SPEEDUP_WINS
    );
}

#[test]
fn speedup_never_increases_with_batch_on_average() {
    // §4.1: the advantage shrinks as batch grows. Check the geomean
    // speedup per batch size is (weakly) decreasing overall.
    let cases = run_sweep();
    let mut prev: Option<f64> = None;
    for &batch in zoo::BATCH_SIZES.iter() {
        let s: Vec<f64> =
            cases.iter().filter(|c| c.batch == batch).map(|c| c.speedup).collect();
        let g = geomean(&s);
        if let Some(p) = prev {
            assert!(
                g <= p * 1.10,
                "geomean speedup rose from {p:.3} to {g:.3} at batch {batch}"
            );
        }
        prev = Some(g);
    }
}

#[test]
fn three_by_three_is_cuconvs_weakest_filter_size() {
    // Figure 6's message: 3x3 is where cuConv is least competitive
    // (Winograd territory).
    let cases = run_sweep();
    let geo = |f: FilterSize| {
        let v: Vec<f64> = cases
            .iter()
            .filter(|c| c.filter == f && c.batch == 1)
            .map(|c| c.speedup)
            .collect();
        geomean(&v)
    };
    let g1 = geo(FilterSize::F1x1);
    let g3 = geo(FilterSize::F3x3);
    let g5 = geo(FilterSize::F5x5);
    assert!(g3 < g1, "3x3 geomean {g3:.2} !< 1x1 {g1:.2}");
    assert!(g3 < g5, "3x3 geomean {g3:.2} !< 5x5 {g5:.2}");
}

#[test]
fn winograd_best_baseline_for_most_3x3() {
    // "the two based on Winograd" dominate 3x3 configs.
    let mut wino_best = 0;
    let mut total = 0;
    for entry in zoo::configs_with_filter(FilterSize::F3x3) {
        let spec = entry.spec; // batch 1
        if let Some(best) = gpumodel::best_baseline(&spec) {
            total += 1;
            if matches!(best.algo, Algorithm::Winograd | Algorithm::WinogradNonfused) {
                wino_best += 1;
            }
        }
    }
    assert!(
        wino_best * 2 > total,
        "winograd best in only {wino_best}/{total} 3x3 configs"
    );
}
