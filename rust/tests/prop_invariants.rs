//! Cross-module property tests (mini-proptest from `cuconv::util::prop`).

use cuconv::algo::Algorithm;
use cuconv::backend::{Backend, ConvDescriptor, CpuRefBackend, Workspace};
use cuconv::conv::ConvSpec;
use cuconv::cpuref::naive::conv_naive;
use cuconv::gpumodel;
use cuconv::tensor::Tensor;
use cuconv::util::json::{parse, Json};
use cuconv::util::prop::{assert_prop, Config, Gen, UsizeIn, VecOf};
use cuconv::util::rng::Rng;

/// Generator for small random valid stride-1 same-padded conv specs.
struct SpecGen;

impl Gen for SpecGen {
    type Value = ConvSpec;

    fn gen(&self, rng: &mut Rng) -> ConvSpec {
        let k = *rng.choose(&[1usize, 3, 5]);
        let hw = rng.range(k.max(3), 12);
        ConvSpec::paper(
            hw,
            rng.range(1, 3),
            k,
            rng.range(1, 12),
            rng.range(1, 12),
        )
    }

    fn shrink(&self, v: &ConvSpec) -> Vec<ConvSpec> {
        let mut out = Vec::new();
        if v.n > 1 {
            out.push(ConvSpec { n: 1, ..*v });
        }
        if v.m > 1 {
            out.push(ConvSpec { m: 1, ..*v });
        }
        if v.c > 1 {
            out.push(ConvSpec { c: 1, ..*v });
        }
        out
    }
}

#[test]
fn prop_same_padding_preserves_spatial_dims() {
    assert_prop(Config::default(), &SpecGen, |spec| {
        if spec.out_h() != spec.h || spec.out_w() != spec.w {
            return Err(format!("out {}x{}", spec.out_h(), spec.out_w()));
        }
        Ok(())
    });
}

#[test]
fn prop_flops_scale_linearly_in_batch() {
    assert_prop(Config::default(), &SpecGen, |spec| {
        let f1 = spec.flops();
        let f4 = spec.with_batch(spec.n * 4).flops();
        if f4 != 4 * f1 {
            return Err(format!("{f1} -> {f4}"));
        }
        Ok(())
    });
}

#[test]
fn prop_backend_algorithms_agree_on_random_specs() {
    let cfg = Config { cases: 24, ..Config::default() };
    let backend = CpuRefBackend::new();
    assert_prop(cfg, &SpecGen, |spec| {
        let mut rng = Rng::new(spec.flops() ^ 0x5EED);
        let input = Tensor::random(spec.n, spec.c, spec.h, spec.w, &mut rng, -1.0, 1.0);
        let filters = Tensor::random(spec.m, spec.c, spec.kh, spec.kw, &mut rng, -1.0, 1.0);
        let want = conv_naive(spec, &input, &filters);
        let desc = ConvDescriptor::new(*spec).map_err(|e| e.to_string())?;
        let mut workspace = Workspace::new();
        for algo in backend.supported_algorithms(spec) {
            let plan = backend.plan(&desc, algo).map_err(|e| e.to_string())?;
            let got = backend
                .execute(&plan, &input, &filters, &mut workspace)
                .map_err(|e| e.to_string())?;
            let err = got.rel_l2_error(&want);
            if err > 5e-4 {
                return Err(format!("{algo} err {err} on {spec}"));
            }
        }
        Ok(())
    });
}

/// Generator for small conv specs across the full parameter space the
/// cuConv kernels must handle: 1×1/3×3/5×5 filters, stride 1–2, and
/// independent (possibly asymmetric, possibly zero) padding.
struct WideSpecGen;

impl Gen for WideSpecGen {
    type Value = ConvSpec;

    fn gen(&self, rng: &mut Rng) -> ConvSpec {
        let k = *rng.choose(&[1usize, 3, 5]);
        let hw = rng.range(k.max(3), 12);
        ConvSpec {
            stride: rng.range(1, 2),
            pad_h: rng.range(0, 2),
            pad_w: rng.range(0, 2),
            ..ConvSpec::paper(hw, rng.range(1, 3), k, rng.range(1, 8), rng.range(1, 8))
        }
    }

    fn shrink(&self, v: &ConvSpec) -> Vec<ConvSpec> {
        let mut out = Vec::new();
        if v.n > 1 {
            out.push(ConvSpec { n: 1, ..*v });
        }
        if v.m > 1 {
            out.push(ConvSpec { m: 1, ..*v });
        }
        if v.c > 1 {
            out.push(ConvSpec { c: 1, ..*v });
        }
        if v.stride > 1 {
            out.push(ConvSpec { stride: 1, ..*v });
        }
        if v.pad_h != v.pad_w {
            out.push(ConvSpec { pad_h: v.pad_w, ..*v });
        }
        out
    }
}

/// The fused single-pass cuConv, the staged two-pass decomposition and
/// the clear-loop oracle must agree across the stride/padding/1×1 sweep
/// — the correctness contract of the fused rewrite.
#[test]
fn prop_cuconv_fused_equals_staged_equals_oracle() {
    use cuconv::cpuref::cuconv::{conv_fused_with_threads, conv_two_stage};
    let cfg = Config { cases: 48, ..Config::default() };
    assert_prop(cfg, &WideSpecGen, |spec| {
        if !spec.is_valid() {
            return Ok(()); // e.g. 5x5 filter on a small unpadded input
        }
        let mut rng = Rng::new(spec.flops() ^ 0xF05ED);
        let input = Tensor::random(spec.n, spec.c, spec.h, spec.w, &mut rng, -1.0, 1.0);
        let filters = Tensor::random(spec.m, spec.c, spec.kh, spec.kw, &mut rng, -1.0, 1.0);
        let oracle = conv_naive(spec, &input, &filters);
        let staged = conv_two_stage(spec, &input, &filters);
        let err = staged.rel_l2_error(&oracle);
        if err > 1e-5 {
            return Err(format!("staged vs oracle err {err}"));
        }
        for threads in [1, 3] {
            let fused = conv_fused_with_threads(spec, &input, &filters, threads);
            let err = fused.rel_l2_error(&oracle);
            if err > 1e-5 {
                return Err(format!("fused({threads}t) vs oracle err {err}"));
            }
            let err = fused.rel_l2_error(&staged);
            if err > 1e-5 {
                return Err(format!("fused({threads}t) vs staged err {err}"));
            }
        }
        Ok(())
    });
}

/// The register-tiled microkernel must agree with the clear-loop oracle
/// **bit for bit** — same `(c, ky, kx)` accumulation order, same
/// mul-then-add rounding — on every tile-shape candidate and thread
/// count, across the random stride/padding/1×1 sweep. The generator's
/// `m ∈ [1, 8)` leaves tail tiles for every MR in the candidate set.
#[test]
fn prop_cuconv_tiled_is_bit_identical_to_oracle() {
    use cuconv::cpuref::cuconv::conv_tiled;
    use cuconv::cpuref::pack::TileShape;
    let cfg = Config { cases: 32, ..Config::default() };
    assert_prop(cfg, &WideSpecGen, |spec| {
        if !spec.is_valid() {
            return Ok(());
        }
        let mut rng = Rng::new(spec.flops() ^ 0x7173D);
        let input = Tensor::random(spec.n, spec.c, spec.h, spec.w, &mut rng, -1.0, 1.0);
        let filters = Tensor::random(spec.m, spec.c, spec.kh, spec.kw, &mut rng, -1.0, 1.0);
        let oracle = conv_naive(spec, &input, &filters);
        for tile in TileShape::CANDIDATES {
            for threads in [1, 3] {
                let got = conv_tiled(spec, &input, &filters, tile, threads);
                let d = got.max_abs_diff(&oracle);
                if d != 0.0 {
                    return Err(format!(
                        "tiled {tile} ({threads}t) differs by {d} on {spec}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Fixed hard cases the random generator cannot reach: AlexNet conv1
/// (11×11 stride 4 on a 227×227 input), stride-2 5×5, heavily
/// asymmetric padding — tiled (every tile shape) == naive bit-exactly,
/// and fused == staged == naive within float tolerance, all four paths
/// on one problem.
#[test]
fn tiled_fused_staged_and_oracle_agree_on_hard_cases() {
    use cuconv::cpuref::cuconv::{conv_fused_with_threads, conv_tiled, conv_two_stage};
    use cuconv::cpuref::pack::TileShape;
    let specs = [
        // AlexNet conv1 geometry (m trimmed 64 -> 9: tails for all MR).
        ConvSpec {
            n: 1, c: 3, h: 227, w: 227, m: 9, kh: 11, kw: 11,
            stride: 4, pad_h: 0, pad_w: 0,
        },
        ConvSpec { stride: 2, ..ConvSpec::paper(13, 1, 5, 6, 4) },
        ConvSpec { pad_h: 0, pad_w: 3, ..ConvSpec::paper(8, 2, 3, 5, 2) },
        ConvSpec { stride: 3, ..ConvSpec::paper(10, 1, 5, 7, 2) },
    ];
    for (i, spec) in specs.iter().enumerate() {
        assert!(spec.is_valid(), "bad hard case {spec}");
        let mut rng = Rng::new(0xA1E7 + i as u64);
        let input = Tensor::random(spec.n, spec.c, spec.h, spec.w, &mut rng, -1.0, 1.0);
        let filters = Tensor::random(spec.m, spec.c, spec.kh, spec.kw, &mut rng, -1.0, 1.0);
        let oracle = conv_naive(spec, &input, &filters);
        for tile in TileShape::CANDIDATES {
            let tiled = conv_tiled(spec, &input, &filters, tile, 2);
            assert_eq!(
                tiled.max_abs_diff(&oracle),
                0.0,
                "tiled {tile} not bit-identical on {spec}"
            );
        }
        let fused = conv_fused_with_threads(spec, &input, &filters, 2);
        assert!(fused.rel_l2_error(&oracle) < 1e-5, "fused vs oracle on {spec}");
        let staged = conv_two_stage(spec, &input, &filters);
        assert!(staged.rel_l2_error(&oracle) < 1e-5, "staged vs oracle on {spec}");
    }
}

#[test]
fn prop_cuconv_temp_accounting_matches_stage1_size() {
    assert_prop(Config::default(), &SpecGen, |spec| {
        let expected = if spec.kh == 1 {
            0
        } else {
            spec.kh * spec.kw * spec.output_elems() * 4
        };
        if spec.cuconv_temp_bytes() != expected {
            return Err(format!("{} != {expected}", spec.cuconv_temp_bytes()));
        }
        Ok(())
    });
}

#[test]
fn prop_gpumodel_time_monotone_in_batch() {
    // More work at equal-or-better occupancy can't get cheaper.
    let cfg = Config { cases: 64, ..Config::default() };
    assert_prop(cfg, &SpecGen, |spec| {
        for algo in Algorithm::ALL {
            let t1 = gpumodel::predict(spec, algo).map(|t| t.total_us());
            let t4 = gpumodel::predict(&spec.with_batch(spec.n * 4), algo)
                .map(|t| t.total_us());
            if let (Some(a), Some(b)) = (t1, t4) {
                if b < a * 0.999 {
                    return Err(format!("{algo}: batch x4 {b} < {a}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gpumodel_speedup_finite_and_positive() {
    let cfg = Config { cases: 128, ..Config::default() };
    assert_prop(cfg, &SpecGen, |spec| {
        if let Some(s) = gpumodel::speedup(spec) {
            if !(s.is_finite() && s > 0.0) {
                return Err(format!("speedup {s}"));
            }
        }
        Ok(())
    });
}

/// JSON generator: nested values from numbers/strings/arrays.
struct JsonGen;

impl Gen for JsonGen {
    type Value = Json;

    fn gen(&self, rng: &mut Rng) -> Json {
        gen_json(rng, 3)
    }
}

fn gen_json(rng: &mut Rng, depth: usize) -> Json {
    // range() is inclusive; at depth 0 only scalar variants (0..=2) are
    // allowed, otherwise recursion would never terminate.
    let pick = rng.range(0, if depth == 0 { 2 } else { 4 });
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 1),
        2 => {
            // Integers + fractional values (printable f64s).
            let v = (rng.next_f64() - 0.5) * 1e6;
            Json::Num((v * 100.0).round() / 100.0)
        }
        3 => {
            let n = rng.range(0, 4);
            Json::Arr((0..n).map(|_| gen_json(rng, depth - 1)).collect())
        }
        _ => {
            let n = rng.range(0, 4);
            Json::Obj(
                (0..n)
                    .map(|i| {
                        (format!("k{}_{}", i, rng.below(100)), gen_json(rng, depth - 1))
                    })
                    .collect(),
            )
        }
    }
}

#[test]
fn prop_json_roundtrips() {
    let cfg = Config { cases: 300, ..Config::default() };
    assert_prop(cfg, &JsonGen, |v| {
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            match parse(&text) {
                Ok(back) if &back == v => {}
                Ok(back) => return Err(format!("{v:?} -> {text} -> {back:?}")),
                Err(e) => return Err(format!("{v:?} -> {text}: {e}")),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tensor_pad_preserves_sum() {
    let gen = VecOf { elem: UsizeIn { lo: 1, hi: 6 }, min_len: 4, max_len: 4 };
    assert_prop(Config::default(), &gen, |dims| {
        let (n, c, h, w) = (dims[0], dims[1], dims[2], dims[3]);
        let mut rng = Rng::new((n * 37 + c * 11 + h * 5 + w) as u64);
        let t = Tensor::random(n, c, h, w, &mut rng, -1.0, 1.0);
        let p = t.pad_hw(2, 1);
        let s0: f32 = t.data().iter().sum();
        let s1: f32 = p.data().iter().sum();
        if (s0 - s1).abs() > 1e-3 {
            return Err(format!("{s0} vs {s1}"));
        }
        if p.shape() != [n, c, h + 4, w + 2] {
            return Err(format!("shape {:?}", p.shape()));
        }
        Ok(())
    });
}
