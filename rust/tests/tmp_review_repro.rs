use cuconv::http::parser::{lazy_scan, span_str};

#[test]
fn span_str_out_of_bounds_on_number_at_eof() {
    let body = br#"{"batch":1,"deadline_ms":1,"tenant":"t","payload":[],"model":1"#;
    let spans = lazy_scan(body, &["model","batch","deadline_ms","tenant","payload"]).unwrap();
    let m = spans[0].as_ref().unwrap().clone();
    assert_eq!(m.end, body.len());
    // This call panics with slice index out of range if the bug is real.
    let r = span_str(body, &m);
    println!("span_str -> {:?}", r);
}
