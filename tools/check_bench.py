#!/usr/bin/env python3
"""Validate the machine-readable bench reports (BENCH_*.json).

The benches are plain binaries that print tables *and* write JSON; a
harness bug (or a bench silently skipping its workload) would otherwise
produce an empty/garbage report that nobody notices until the perf
trajectory is needed. CI runs this after every bench step, so an empty
or insane report fails the build instead of landing.

Checks per file:
  * parses as JSON, top-level object, correct ``bench`` tag;
  * every required list is present and non-empty;
  * every timing/throughput field is a finite, strictly positive number
    (the JSON writer emits ``null`` for NaN/Inf — also rejected);
  * per-file invariants (e.g. the serve scaling curve covers the
    worker counts it promises and accounts every offered request).

Usage:
    python3 tools/check_bench.py BENCH_hotpath.json BENCH_e2e.json ...
    python3 tools/check_bench.py --baseline DIR BENCH_tune.json ...

With ``--baseline DIR``, each report is additionally compared against
the committed baseline of the same file name in DIR (see
tools/baselines/): machine-independent relative metrics are extracted
from the report, divided by the baseline's recorded values, and the
geometric mean of those ratios must stay within the baseline's
``tolerance`` factor. A regressed geomean, a missing baseline file, or
a malformed tolerance each fail the run.

Exits non-zero listing every violation (not just the first).
"""

from __future__ import annotations

import json
import math
import os
import sys

PROBLEMS: list[str] = []


def problem(path: str, msg: str) -> None:
    PROBLEMS.append(f"{path}: {msg}")


def finite_positive(path: str, row: dict, key: str, where: str) -> None:
    v = row.get(key)
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        problem(path, f"{where}: '{key}' is {v!r}, expected a number")
        return
    if not math.isfinite(v) or v <= 0.0:
        problem(path, f"{where}: '{key}' = {v!r} is not finite and positive")


def nonneg_count(path: str, row: dict, key: str, where: str) -> None:
    v = row.get(key)
    if not isinstance(v, int) or isinstance(v, bool) or v < 0:
        problem(path, f"{where}: '{key}' is {v!r}, expected a count >= 0")


def non_empty_rows(path: str, doc: dict, key: str) -> list:
    rows = doc.get(key)
    if not isinstance(rows, list) or not rows:
        problem(path, f"'{key}' is missing or empty — did the bench run its workload?")
        return []
    bad = [r for r in rows if not isinstance(r, dict)]
    if bad:
        problem(path, f"'{key}' contains non-object rows")
        return []
    return rows


def check_hotpath(path: str, doc: dict) -> None:
    for row in non_empty_rows(path, doc, "execute_alloc_vs_reuse"):
        where = f"execute_alloc_vs_reuse[{row.get('algo')!r}]"
        if not row.get("algo"):
            problem(path, f"{where}: missing 'algo'")
        for key in ("alloc_p50_us", "reuse_p50_us", "speedup"):
            finite_positive(path, row, key, where)
    for row in non_empty_rows(path, doc, "cuconv_staged_vs_fused"):
        where = f"cuconv_staged_vs_fused[{row.get('config')!r}]"
        for key in ("staged_alloc_p50_us", "fused_reuse_p50_us", "speedup"):
            finite_positive(path, row, key, where)
    # Register-tiled microkernel vs the untiled fused kernel: rows must
    # exist, carry a tile label, time out finite and positive, and
    # attest bit-identity with the naive oracle (the bench asserts it
    # before timing; a false here means the assertion was bypassed).
    for row in non_empty_rows(path, doc, "cuconv_tiled_vs_fused"):
        where = f"cuconv_tiled_vs_fused[{row.get('config')!r}]"
        if not row.get("tile"):
            problem(path, f"{where}: missing 'tile'")
        for key in ("fused_p50_us", "tiled_p50_us", "speedup"):
            finite_positive(path, row, key, where)
        if row.get("bit_identical") is not True:
            problem(path, f"{where}: 'bit_identical' is {row.get('bit_identical')!r}")
    finite_positive(path, doc, "tiled_geomean_speedup", "top level")
    # The MR x NR sweep must have run the whole candidate set (mirror
    # of TileShape::CANDIDATES in rust/src/cpuref/pack.rs — update both
    # together): a truncated sweep must fail here, not land silently.
    tile_candidates = {"2x8", "4x8", "8x8", "4x4"}
    sweep = non_empty_rows(path, doc, "tile_sweep")
    tiles = [r.get("tile") for r in sweep]
    if len(set(tiles)) != len(tiles):
        problem(path, f"tile_sweep has duplicate tiles: {tiles}")
    if sweep and set(tiles) != tile_candidates:
        problem(
            path,
            f"tile_sweep covered {sorted(set(tiles))}, "
            f"expected the full candidate set {sorted(tile_candidates)}",
        )
    for row in sweep:
        where = f"tile_sweep[{row.get('tile')!r}]"
        if not row.get("tile"):
            problem(path, f"{where}: missing 'tile'")
        finite_positive(path, row, "p50_us", where)
    # Blocked NCHWc layout vs the tiled NCHW kernel: the bench asserts
    # bit-identity against conv_naive before timing, so a false here
    # means the assertion was bypassed. The report also records which
    # SIMD level actually ran (scalar results are valid but a CI run
    # silently losing AVX2 should be visible in the artifact).
    if not isinstance(doc.get("simd_level"), str) or not doc.get("simd_level"):
        problem(path, f"'simd_level' is {doc.get('simd_level')!r}, expected a name")
    for row in non_empty_rows(path, doc, "cuconv_blocked_vs_tiled"):
        where = f"cuconv_blocked_vs_tiled[{row.get('config')!r}]"
        if not row.get("config"):
            problem(path, f"{where}: missing 'config'")
        for key in ("tiled_p50_us", "blocked_p50_us", "speedup"):
            finite_positive(path, row, key, where)
        if row.get("bit_identical") is not True:
            problem(path, f"{where}: 'bit_identical' is {row.get('bit_identical')!r}")
    finite_positive(path, doc, "blocked_geomean_speedup", "top level")
    # The inverted form feeds the --baseline gate (lower is better, so
    # a blocked-layout slowdown raises it past the tolerance).
    finite_positive(path, doc, "tiled_over_blocked", "top level")
    geo = doc.get("blocked_geomean_speedup")
    inv = doc.get("tiled_over_blocked")
    if (
        isinstance(geo, (int, float))
        and isinstance(inv, (int, float))
        and not isinstance(geo, bool)
        and not isinstance(inv, bool)
        and math.isfinite(geo)
        and math.isfinite(inv)
        and geo > 0
        and abs(inv * geo - 1.0) > 1e-9
    ):
        problem(
            path,
            f"'tiled_over_blocked' = {inv!r} is not the inverse of "
            f"'blocked_geomean_speedup' = {geo!r}",
        )


def check_e2e(path: str, doc: dict) -> None:
    rows = non_empty_rows(path, doc, "networks")
    names = [r.get("network") for r in rows]
    if len(set(names)) != len(names):
        problem(path, f"duplicate network rows: {names}")
    for row in rows:
        where = f"networks[{row.get('network')!r}]"
        for key in ("latency_ms", "conv_ms", "modeled_network_speedup"):
            finite_positive(path, row, key, where)
        share = row.get("conv_share")
        if not isinstance(share, (int, float)) or not (0.0 < float(share) <= 1.0):
            problem(path, f"{where}: conv_share {share!r} outside (0, 1]")
        for key in ("nodes", "conv_nodes", "arena_bytes"):
            finite_positive(path, row, key, where)


def check_serve(path: str, doc: dict) -> None:
    points = non_empty_rows(path, doc, "points")
    offered = doc.get("requests_per_point")
    workers_seen = []
    classes = ("completed", "rejected", "failed", "expired")
    for row in points:
        where = f"points[workers={row.get('workers')!r}]"
        for key in ("workers", "rps"):
            finite_positive(path, row, key, where)
        for key in classes:
            nonneg_count(path, row, key, where)
        if isinstance(offered, int) and all(
            isinstance(row.get(k), int) for k in classes
        ):
            total = sum(row[k] for k in classes)
            if total != offered:
                problem(
                    path,
                    f"{where}: completed+rejected+failed+expired = {total} "
                    f"!= offered {offered}",
                )
        if isinstance(row.get("completed"), int) and row.get("completed", 0) > 0:
            for key in ("p50_ms", "p99_ms"):
                finite_positive(path, row, key, where)
        workers_seen.append(row.get("workers"))
    if workers_seen and workers_seen != sorted(set(workers_seen)):
        problem(path, f"worker counts not strictly increasing: {workers_seen}")
    if 1 not in workers_seen:
        problem(path, "scaling curve lacks the 1-worker baseline point")


def check_http(path: str, doc: dict) -> None:
    offered = doc.get("requests_per_point")
    classes = ("completed", "rejected", "failed", "expired")
    points = non_empty_rows(path, doc, "points")
    labels = [r.get("point") for r in points]
    if len(set(labels)) != len(labels):
        problem(path, f"duplicate point labels: {labels}")
    any_expired = False
    for row in points:
        where = f"points[{row.get('point')!r}]"
        if not row.get("point"):
            problem(path, f"{where}: missing 'point' label")
        finite_positive(path, row, "clients", where)
        for key in classes:
            nonneg_count(path, row, key, where)
        if isinstance(offered, int) and all(
            isinstance(row.get(k), int) for k in classes
        ):
            total = sum(row[k] for k in classes)
            if total != offered:
                problem(
                    path,
                    f"{where}: completed+rejected+failed+expired = {total} "
                    f"!= offered {offered}",
                )
        if isinstance(row.get("expired"), int) and row["expired"] > 0:
            any_expired = True
        # Latency fields exist — finite and positive — exactly when
        # something completed; a point with zero completions must not
        # smuggle in a latency (there is nothing to measure).
        if isinstance(row.get("completed"), int) and row["completed"] > 0:
            for key in ("rps", "p50_ms", "p99_ms"):
                finite_positive(path, row, key, where)
        else:
            for key in ("p50_ms", "p99_ms"):
                if key in row:
                    problem(
                        path,
                        f"{where}: '{key}' present with zero completed requests",
                    )
    if points and not any_expired:
        problem(
            path,
            "no point exercised the expired path "
            "(the dead-on-arrival point is part of the bench contract)",
        )
    # SLO attainment buckets: present, bounds strictly increasing,
    # cumulative counts monotone non-decreasing.
    slo = doc.get("slo")
    if not isinstance(slo, list) or not slo:
        problem(path, "'slo' buckets missing or empty")
    else:
        prev_le, prev_count = 0.0, -1
        for i, b in enumerate(slo):
            if not isinstance(b, dict):
                problem(path, f"slo[{i}] is not an object")
                continue
            le, count = b.get("le_seconds"), b.get("count")
            if not isinstance(le, (int, float)) or le <= prev_le:
                problem(path, f"slo[{i}]: le_seconds {le!r} not strictly increasing")
            else:
                prev_le = float(le)
            if not isinstance(count, int) or count < max(prev_count, 0):
                problem(
                    path,
                    f"slo[{i}]: count {count!r} not a cumulative count",
                )
            else:
                prev_count = count
    for key in ("server_requests", "server_expired"):
        nonneg_count(path, doc, key, "top level")


def nonneg_number(path: str, row: dict, key: str, where: str) -> None:
    v = row.get(key)
    if (
        not isinstance(v, (int, float))
        or isinstance(v, bool)
        or not math.isfinite(v)
        or v < 0
    ):
        problem(path, f"{where}: '{key}' is {v!r}, expected a finite number >= 0")


def check_watchdog_scenario(path: str, s: dict, where: str) -> None:
    """Shared checks for the two watchdog scenarios (``stall-eviction``
    and ``soak``): the stall budget is a real duration, at least one
    hung worker was actually evicted, every eviction respawned a
    replacement, and the fenced-discard counter is a sane count.
    ``stall-eviction`` additionally bounds the measured eviction
    latency: at or after the budget (the watchdog must not fire early)
    but within 50x of it (later than that and the 'detection' was just
    the stall ending on its own). ``soak`` additionally requires at
    least one completed round and a positive measured wall-clock."""
    finite_positive(path, s, "stall_budget_ms", where)
    budget = s.get("stall_budget_ms")
    evictions = s.get("stalled_evictions")
    if not isinstance(evictions, int) or isinstance(evictions, bool) or evictions < 1:
        problem(
            path,
            f"{where}: 'stalled_evictions' is {evictions!r} — the watchdog "
            "never evicted a hung worker",
        )
    restarts = s.get("restarts")
    if (
        isinstance(evictions, int)
        and isinstance(restarts, int)
        and restarts < evictions
    ):
        problem(
            path,
            f"{where}: {restarts} restart(s) < {evictions} eviction(s) — "
            "an evicted worker was never replaced",
        )
    nonneg_count(path, s, "fenced_discards", where)
    if s.get("scenario") == "stall-eviction":
        finite_positive(path, s, "eviction_latency_ms", where)
        lat = s.get("eviction_latency_ms")
        if (
            isinstance(lat, (int, float))
            and isinstance(budget, (int, float))
            and not isinstance(lat, bool)
            and not isinstance(budget, bool)
            and math.isfinite(lat)
            and math.isfinite(budget)
            and budget > 0
        ):
            if lat < budget:
                problem(
                    path,
                    f"{where}: eviction_latency_ms {lat!r} precedes the "
                    f"stall budget {budget!r} — the watchdog fired early",
                )
            elif lat > 50 * budget:
                problem(
                    path,
                    f"{where}: eviction_latency_ms {lat!r} is over 50x the "
                    f"stall budget {budget!r} — not a plausible detection",
                )
        discards = s.get("fenced_discards")
        requests = s.get("requests")
        if (
            isinstance(discards, int)
            and isinstance(requests, int)
            and discards > requests
        ):
            problem(
                path,
                f"{where}: fenced_discards {discards} > requests {requests}",
            )
        if isinstance(discards, int) and discards < 1:
            problem(
                path,
                f"{where}: 'fenced_discards' is 0 — the evicted worker's "
                "late completion was never fenced off",
            )
    if s.get("scenario") == "soak":
        rounds = s.get("rounds")
        if not isinstance(rounds, int) or isinstance(rounds, bool) or rounds < 1:
            problem(
                path,
                f"{where}: 'rounds' is {rounds!r}, expected a count >= 1",
            )
        finite_positive(path, s, "soak_seconds", where)


def check_chaos(path: str, doc: dict) -> None:
    """The chaos contract: every scenario accounts every request in
    exactly one of the four classes per priority with zero lost, panic
    recovery actually happened somewhere with a finite recovery time,
    every pool ends restored, and the recovered pool's outputs are
    bit-identical to the unfaulted reference.

    Two scenarios are *required by name*: ``stall-eviction`` (the
    watchdog evicted a hung worker inside a plausible latency window —
    at or after the stall budget, but not absurdly later — with a
    replacement respawned per eviction and the late completion fenced
    off) and ``soak`` (a wall-clock loop of seeded chaos rounds whose
    accumulated accounting still closes exactly)."""
    classes = ("completed", "rejected", "failed", "expired")
    priorities = {"interactive", "batch"}
    scenarios = non_empty_rows(path, doc, "scenarios")
    names = [s.get("scenario") for s in scenarios]
    if len(set(names)) != len(names):
        problem(path, f"duplicate scenario names: {names}")
    any_restart = False
    for s in scenarios:
        where = f"scenarios[{s.get('scenario')!r}]"
        if not s.get("scenario"):
            problem(path, f"{where}: missing 'scenario' label")
        for key in ("workers", "requests"):
            finite_positive(path, s, key, where)
        nonneg_count(path, s, "restarts", where)
        restarts = s.get("restarts")
        if isinstance(restarts, int) and restarts > 0:
            any_restart = True
        rec = s.get("recovery_max_ms")
        if (
            not isinstance(rec, (int, float))
            or isinstance(rec, bool)
            or not math.isfinite(rec)
            or rec < 0
        ):
            problem(path, f"{where}: recovery_max_ms {rec!r} is not a finite time")
        elif isinstance(restarts, int) and restarts > 0 and rec >= 600_000:
            problem(
                path,
                f"{where}: recovery_max_ms {rec!r} is not a plausible measurement",
            )
        if s.get("pool_restored") is not True:
            problem(path, f"{where}: 'pool_restored' is {s.get('pool_restored')!r}")
        if s.get("lost") != 0:
            problem(
                path,
                f"{where}: 'lost' is {s.get('lost')!r} — the zero-lost "
                "contract is broken",
            )
        rows = s.get("classes")
        if not isinstance(rows, list) or not rows:
            problem(path, f"{where}: 'classes' missing or empty")
            rows = []
        seen = [r.get("priority") for r in rows if isinstance(r, dict)]
        if rows and set(seen) != priorities:
            problem(
                path,
                f"{where}: classes cover {sorted(set(seen))}, "
                f"expected exactly {sorted(priorities)}",
            )
        for r in rows:
            if not isinstance(r, dict):
                problem(path, f"{where}: non-object class row")
                continue
            cw = f"{where}.classes[{r.get('priority')!r}]"
            nonneg_count(path, r, "offered", cw)
            for key in classes:
                nonneg_count(path, r, key, cw)
            if all(isinstance(r.get(k), int) for k in ("offered",) + classes):
                total = sum(r[k] for k in classes)
                if total != r["offered"]:
                    problem(
                        path,
                        f"{cw}: completed+rejected+failed+expired = {total} "
                        f"!= offered {r['offered']}",
                    )
            if r.get("lost") != 0:
                problem(path, f"{cw}: 'lost' is {r.get('lost')!r}, must be 0")
        curve = s.get("shed_curve")
        if curve is not None:
            if not isinstance(curve, list) or not curve:
                problem(path, f"{where}: 'shed_curve' present but empty")
                curve = []
            for p in curve:
                if not isinstance(p, dict):
                    problem(path, f"{where}: non-object shed_curve point")
                    continue
                pw = f"{where}.shed_curve[clients={p.get('clients')!r}]"
                finite_positive(path, p, "clients", pw)
                for cls in ("interactive", "batch"):
                    nonneg_count(path, p, f"{cls}_offered", pw)
                    nonneg_count(path, p, f"{cls}_rejected", pw)
                    frac = p.get(f"{cls}_rejected_frac")
                    if (
                        not isinstance(frac, (int, float))
                        or isinstance(frac, bool)
                        or not math.isfinite(frac)
                        or not 0.0 <= float(frac) <= 1.0
                    ):
                        problem(
                            path,
                            f"{pw}: {cls}_rejected_frac {frac!r} outside [0, 1]",
                        )
                    off, rej = p.get(f"{cls}_offered"), p.get(f"{cls}_rejected")
                    if isinstance(off, int) and isinstance(rej, int) and rej > off:
                        problem(path, f"{pw}: {cls} rejected {rej} > offered {off}")
        if s.get("scenario") in ("stall-eviction", "soak"):
            check_watchdog_scenario(path, s, where)
    if scenarios and not any_restart:
        problem(
            path,
            "no scenario recorded a restart — panic recovery was never exercised",
        )
    for required in ("stall-eviction", "soak"):
        if scenarios and required not in names:
            problem(
                path,
                f"no '{required}' scenario — the watchdog contract was "
                "never exercised",
            )
    if doc.get("post_recovery_bit_identical") is not True:
        problem(
            path,
            f"'post_recovery_bit_identical' is "
            f"{doc.get('post_recovery_bit_identical')!r}",
        )
    if doc.get("pool_restored") is not True:
        problem(path, f"'pool_restored' is {doc.get('pool_restored')!r}")


def check_tune(path: str, doc: dict) -> None:
    """The warm-start contract: a warm plan must be cheaper than the
    cold one it replays, measure nothing, miss nothing, and reproduce
    the cold choices through a bit-identical file round trip."""
    if not doc.get("network"):
        problem(path, "missing 'network'")
    for key in ("cold_plan_ms", "warm_plan_ms", "speedup"):
        finite_positive(path, doc, key, "top level")
    cold, warm = doc.get("cold_plan_ms"), doc.get("warm_plan_ms")
    if (
        isinstance(cold, (int, float))
        and isinstance(warm, (int, float))
        and not isinstance(cold, bool)
        and not isinstance(warm, bool)
        and not warm < cold
    ):
        problem(
            path,
            f"warm plan ({warm!r} ms) is not faster than cold ({cold!r} ms) — "
            "the cache bought nothing",
        )
    # Cold planning must actually have measured; warm planning must not
    # have measured or missed at all — that is the whole point.
    finite_positive(path, doc, "cold_measurements", "top level")
    nonneg_count(path, doc, "warm_measurements", "top level")
    if doc.get("warm_measurements") != 0:
        problem(
            path,
            f"'warm_measurements' is {doc.get('warm_measurements')!r} — "
            "the warm plan ran timing measurements",
        )
    finite_positive(path, doc, "warm_hits", "top level")
    nonneg_count(path, doc, "warm_misses", "top level")
    if doc.get("warm_misses") != 0:
        problem(
            path,
            f"'warm_misses' is {doc.get('warm_misses')!r} — warm planning "
            "fell through the cache",
        )
    finite_positive(path, doc, "entries", "top level")
    for key in ("choices_identical", "roundtrip_bit_identical"):
        if doc.get(key) is not True:
            problem(path, f"'{key}' is {doc.get(key)!r}")


CHECKERS = {
    "hotpath_micro": check_hotpath,
    "e2e_forward": check_e2e,
    "serve_scaling": check_serve,
    "http_serving": check_http,
    "chaos_serving": check_chaos,
    "tune_cache": check_tune,
}


def tune_baseline_metrics(doc: dict) -> dict:
    """Machine-independent relative metrics of a tune_cache report: the
    warm/cold plan-time ratio (absolute times vary with the runner; the
    ratio is the cache's value and regresses when warm planning starts
    re-measuring)."""
    cold, warm = doc.get("cold_plan_ms"), doc.get("warm_plan_ms")
    if (
        isinstance(cold, (int, float))
        and isinstance(warm, (int, float))
        and not isinstance(cold, bool)
        and not isinstance(warm, bool)
        and cold > 0
    ):
        return {"warm_over_cold": float(warm) / float(cold)}
    return {}


def hotpath_baseline_metrics(doc: dict) -> dict:
    """Machine-independent relative metric of a hotpath_micro report:
    the tiled/blocked runtime ratio (inverse of the blocked-layout
    geomean speedup, so lower is better and a blocked regression raises
    it). Absolute microseconds vary with the runner; the ratio is the
    layout's value."""
    v = doc.get("tiled_over_blocked")
    if isinstance(v, (int, float)) and not isinstance(v, bool) and math.isfinite(v) and v > 0:
        return {"tiled_over_blocked": float(v)}
    return {}


BASELINE_METRICS = {
    "tune_cache": tune_baseline_metrics,
    "hotpath_micro": hotpath_baseline_metrics,
}


def compare_baseline(path: str, doc: dict, baseline_dir: str) -> None:
    """Gate `doc` against the committed baseline of the same file name:
    geomean(current metric / baseline metric) must not exceed the
    baseline's tolerance factor."""
    bench = doc.get("bench")
    extract = BASELINE_METRICS.get(bench)
    if extract is None:
        problem(path, f"bench tag {bench!r} has no baseline metric extractor")
        return
    bpath = os.path.join(baseline_dir, os.path.basename(path))
    try:
        with open(bpath, encoding="utf-8") as f:
            base = json.load(f)
    except OSError:
        problem(path, f"no baseline at {bpath} — commit one to gate this bench")
        return
    except json.JSONDecodeError as e:
        problem(path, f"baseline {bpath} is invalid JSON: {e}")
        return
    if not isinstance(base, dict):
        problem(path, f"baseline {bpath}: top level is not an object")
        return
    if base.get("bench") != bench:
        problem(
            path,
            f"baseline {bpath}: bench tag {base.get('bench')!r} != {bench!r}",
        )
        return
    tol = base.get("tolerance")
    if (
        not isinstance(tol, (int, float))
        or isinstance(tol, bool)
        or not math.isfinite(tol)
        or tol <= 0
    ):
        problem(
            path,
            f"baseline {bpath}: tolerance {tol!r} is not a finite positive factor",
        )
        return
    base_metrics = base.get("metrics")
    if not isinstance(base_metrics, dict) or not base_metrics:
        problem(path, f"baseline {bpath}: 'metrics' missing or empty")
        return
    current = extract(doc)
    ratios = []
    for key in sorted(base_metrics):
        bval = base_metrics[key]
        if (
            not isinstance(bval, (int, float))
            or isinstance(bval, bool)
            or not math.isfinite(bval)
            or bval <= 0
        ):
            problem(
                path,
                f"baseline {bpath}: metric '{key}' = {bval!r} "
                "is not finite and positive",
            )
            return
        cval = current.get(key)
        if cval is None:
            problem(path, f"report lacks baseline metric '{key}'")
            return
        if not math.isfinite(cval) or cval <= 0:
            problem(path, f"metric '{key}' = {cval!r} is not finite and positive")
            return
        ratios.append(cval / bval)
    geomean = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
    if geomean > tol:
        problem(
            path,
            f"geomean regression vs {bpath}: current/baseline = {geomean:.3f}x "
            f"exceeds tolerance {tol:.3f}x over {sorted(base_metrics)}",
        )


def check_file(path: str, baseline_dir: str | None = None) -> None:
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except OSError as e:
        problem(path, f"cannot read: {e}")
        return
    except json.JSONDecodeError as e:
        problem(path, f"invalid JSON: {e}")
        return
    if not isinstance(doc, dict):
        problem(path, "top level is not an object")
        return
    bench = doc.get("bench")
    checker = CHECKERS.get(bench)
    if checker is None:
        problem(path, f"unknown bench tag {bench!r} (expected {sorted(CHECKERS)})")
        return
    checker(path, doc)
    if baseline_dir is not None:
        compare_baseline(path, doc, baseline_dir)


def main(argv: list[str]) -> int:
    args = argv[1:]
    baseline_dir = None
    if args and args[0] == "--baseline":
        if len(args) < 2:
            print(__doc__)
            return 2
        baseline_dir = args[1]
        args = args[2:]
    if not args:
        print(__doc__)
        return 2
    for path in args:
        check_file(path, baseline_dir)
    if PROBLEMS:
        print(f"check_bench: {len(PROBLEMS)} problem(s):")
        for p in PROBLEMS:
            print(f"  FAIL {p}")
        return 1
    suffix = f" (baseline-gated against {baseline_dir})" if baseline_dir else ""
    print(f"check_bench: {len(args)} report(s) OK{suffix}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
