#!/usr/bin/env python3
"""Fit the gpumodel calibration constants from the paper's published
kernel timings (Tables 3-5 of the cuConv paper).

Each kernel family is modeled as an affine law

    t_us = a * (work / occ) + b

where `work` is the family's work feature (MFLOPs for compute kernels,
K-elements for transform kernels) and `occ` is the linear occupancy
min(1, warps/640) of the launch on an 80-SM V100 (640 = 80 SMs x 8
resident warps needed to hide latency).

Run:  python tools/fit_gpumodel.py
Copy the printed constants into rust/src/gpumodel/calib.rs.
"""
import math

SM, WARPS_SAT = 80, 640

def occ(warps): return min(1.0, warps / WARPS_SAT)

def P(hw, n): return hw*hw*n

# ---- measurements: (feature_work, occ, t_us) per family ----
def mf(hw,n,m,c,k): return 2.0*P(hw,n)*m*c*k*k/1e6

def cuconv_s1_warps(hw,n,m,c,k):
    T = min(1024, P(hw,n)); blocks = k*k*m*math.ceil(P(hw,n)/1024)
    return blocks*math.ceil(T/32)

fams = {}

# cuconv stage 1 (scalar_prods_kernel)
pts=[]
for (hw,n,m,c,k,t) in [(7,1,256,832,1,58.56),(14,1,1024,256,1,73.86),(27,1,256,64,1,22.53),
                       (7,1,384,192,3,52.86),(13,1,384,384,3,461.37),
                       (7,1,128,48,5,16.80),(7,8,128,48,5,107.58)]:
    pts.append((mf(hw,n,m,c,k)/occ(cuconv_s1_warps(hw,n,m,c,k)), t))
fams["CUCONV_S1"]=pts

# cuconv stage 2 (sum_kernel): feature = temp K-elements (taps*P*M/1e3)
pts=[]
for (hw,n,m,k,t) in [(7,1,384,3,4.93),(13,1,384,3,5.31),(7,1,128,5,5.70),(7,8,128,5,9.02)]:
    pts.append((k*k*P(hw,n)*m/1e3, t))
fams["CUCONV_S2"]=pts

# gemm implicit (32x32 tiles, 256 threads)
pts=[]
for (hw,n,m,c,k,t) in [(7,1,256,832,1,128.13),(14,1,1024,256,1,47.87),(27,1,256,64,1,19.20)]:
    blocks = math.ceil(P(hw,n)/32)*math.ceil(m/32)
    pts.append((mf(hw,n,m,c,k)/occ(blocks*8), t))
fams["GEMM_IMPL"]=pts

# gemm implicit precomp main kernel (128x64 tiles, 256 threads); t minus 2us offsets kernel
pts=[]
for (hw,n,m,c,k,t) in [(7,1,256,832,1,105.31),(14,1,1024,256,1,43.23),(27,1,256,64,1,22.40),
                       (7,1,384,192,3,201.47),(13,1,384,384,3,386.97)]:
    blocks = math.ceil(P(hw,n)/128)*math.ceil(m/64)
    pts.append((mf(hw,n,m,c,k)/occ(blocks*8), t))
fams["GEMM_PRECOMP"]=pts

# winograd fused: tiles kernel (feature: N*C*Hp*Wp kelems) + main (wino MFLOPs)
fams["WINO_TILES"]=[(192*81/1e3, 9.12),(384*225/1e3, 19.77)]
def wino_mf(hw,n,m,c): tiles=math.ceil(hw/2)**2*n; return 16*2*m*c*tiles/1e6
fams["WINO_MAIN"]=[(wino_mf(7,1,384,192),101.91),(wino_mf(13,1,384,384),212.58)]

# winograd nonfused (F(4x4): 5x5 uses 8x8 transforms)
fams["NF_DATA"]=[(192*81/1e3,8.06),(384*225/1e3,22.75),(48*121/1e3,13.82),(8*48*121/1e3,13.89)]
fams["NF_FILTER"]=[(384*192/1e3,17.44),(384*384/1e3,35.10),(128*48/1e3,9.15),(128*48/1e3,9.73)]
def nf_mf3(hw,n,m,c): tiles=math.ceil(hw/4)**2*n; return 36*2*m*c*tiles/1e6
def nf_mf5(hw,n,m,c): tiles=math.ceil(hw/4)**2*n; return 64*2*m*c*tiles/1e6
fams["NF_GEMM3"]=[(nf_mf3(7,1,384,192),69.31),(nf_mf3(13,1,384,384),242.56)]
fams["NF_GEMM5"]=[(nf_mf5(7,1,128,48),34.91),(nf_mf5(7,8,128,48),35.36)]
fams["NF_OUT"]=[(384*49/1e3,10.82),(384*169/1e3,27.14),(128*49/1e3,16.92),(8*128*49/1e3,17.60)]

# offsets kernel (constant)
fams["OFFSETS"]=[(0,1.98),(0,2.00),(0,1.89),(0,1.98),(0,2.11)]

for name, pts in sorted(fams.items()):
    xs=[p[0] for p in pts]; ts=[p[1] for p in pts]
    n=len(pts)
    if max(xs)-min(xs) < 1e-9:
        a, b = 0.0, sum(ts)/n
    else:
        mx=sum(xs)/n; mt=sum(ts)/n
        a = sum((x-mx)*(t-mt) for x,t in pts)/sum((x-mx)**2 for x in xs)
        b = mt - a*mx
        if b < 1.0: b = 1.0; a = sum((t-b)*x for x,t in pts)/sum(x*x for x in xs)
        if a < 0: a = 0.0; b = mt
    errs=[(a*x+b)/t for x,t in pts]
    print(f"{name:14s} a={a:8.4f} b={b:8.2f}   ratios: " + " ".join(f"{e:.2f}" for e in errs))
