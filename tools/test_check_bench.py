#!/usr/bin/env python3
"""Self-test for tools/check_bench.py against known-good and mutated
chaos, tune, and hotpath reports, plus the --baseline perf gates.

The checkers are themselves part of the CI contract: if one silently
accepted a report with lost requests, a skipped recovery, or a warm
plan that secretly re-measured, the gate would be decorative. This
script runs the checker on the committed good fixtures (must pass), on
a battery of single-field mutations (each must fail, with the
violation attributed to the right field), and exercises the baseline
gate: a healthy report passes against the committed baseline, while a
synthetically regressed report, a missing baseline file, and a
malformed tolerance each fail with the right message.

Usage:
    python3 tools/test_check_bench.py
"""

from __future__ import annotations

import copy
import json
import os
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
CHECKER = os.path.join(HERE, "check_bench.py")
GOOD = os.path.join(HERE, "fixtures", "BENCH_chaos_good.json")
TUNE_GOOD = os.path.join(HERE, "fixtures", "BENCH_tune_good.json")
HOTPATH_GOOD = os.path.join(HERE, "fixtures", "BENCH_hotpath_good.json")
BASELINES = os.path.join(HERE, "baselines")


def run_checker(
    doc: dict,
    tmpdir: str,
    name: str = "BENCH_chaos.json",
    baseline_dir: str | None = None,
) -> tuple[int, str]:
    path = os.path.join(tmpdir, name)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f)
    cmd = [sys.executable, CHECKER]
    if baseline_dir is not None:
        cmd += ["--baseline", baseline_dir]
    cmd.append(path)
    proc = subprocess.run(
        cmd,
        capture_output=True,
        text=True,
        check=False,
    )
    return proc.returncode, proc.stdout + proc.stderr


def mutations() -> list[tuple[str, object, str]]:
    """(name, mutator, expected-substring-in-output) triples. Each
    mutator edits a deep copy of the good document in place."""

    def wrong_tag(d):
        d["bench"] = "chaos_srving"

    def scenario_lost(d):
        d["scenarios"][0]["lost"] = 2

    def class_lost(d):
        d["scenarios"][0]["classes"][1]["lost"] = 1

    def broken_accounting(d):
        d["scenarios"][1]["classes"][0]["completed"] -= 1

    def no_restarts(d):
        for s in d["scenarios"]:
            s["restarts"] = 0

    def bits_diverged(d):
        d["post_recovery_bit_identical"] = False

    def pool_not_restored(d):
        d["scenarios"][0]["pool_restored"] = False

    def recovery_nan(d):
        # The JSON writer emits null for NaN/Inf — must be rejected.
        d["scenarios"][0]["recovery_max_ms"] = None

    def frac_out_of_range(d):
        d["scenarios"][2]["shed_curve"][1]["batch_rejected_frac"] = 1.5

    def rejected_exceeds_offered(d):
        d["scenarios"][2]["shed_curve"][2]["batch_rejected"] = 99

    def missing_class(d):
        d["scenarios"][0]["classes"] = d["scenarios"][0]["classes"][:1]

    def no_scenarios(d):
        d["scenarios"] = []

    def duplicate_scenarios(d):
        d["scenarios"][1]["scenario"] = d["scenarios"][0]["scenario"]

    def negative_count(d):
        d["scenarios"][2]["classes"][1]["rejected"] = -3

    # Watchdog scenarios: fixture index 3 is stall-eviction, 4 is soak.
    def no_eviction(d):
        d["scenarios"][3]["stalled_evictions"] = 0

    def eviction_before_budget(d):
        d["scenarios"][3]["eviction_latency_ms"] = 10

    def eviction_too_slow(d):
        d["scenarios"][3]["eviction_latency_ms"] = 2500

    def stall_budget_null(d):
        # The JSON writer emits null for NaN/Inf — must be rejected.
        d["scenarios"][3]["stall_budget_ms"] = None

    def fenced_negative(d):
        d["scenarios"][3]["fenced_discards"] = -1

    def fenced_zero(d):
        d["scenarios"][3]["fenced_discards"] = 0

    def fenced_exceeds_requests(d):
        d["scenarios"][3]["fenced_discards"] = 999

    def restarts_below_evictions(d):
        d["scenarios"][4]["restarts"] = 2

    def missing_eviction_scenario(d):
        del d["scenarios"][3]

    def missing_soak_scenario(d):
        del d["scenarios"][4]

    def soak_rounds_zero(d):
        d["scenarios"][4]["rounds"] = 0

    def soak_wall_clock_zero(d):
        d["scenarios"][4]["soak_seconds"] = 0

    return [
        ("wrong bench tag", wrong_tag, "unknown bench tag"),
        ("scenario-level lost", scenario_lost, "zero-lost"),
        ("class-level lost", class_lost, "'lost'"),
        ("broken four-way accounting", broken_accounting, "offered"),
        ("no scenario restarted", no_restarts, "never exercised"),
        ("bit-identity flag false", bits_diverged, "post_recovery_bit_identical"),
        ("pool not restored", pool_not_restored, "pool_restored"),
        ("recovery time is null", recovery_nan, "recovery_max_ms"),
        ("shed frac out of range", frac_out_of_range, "outside [0, 1]"),
        ("rejected exceeds offered", rejected_exceeds_offered, "> offered"),
        ("a priority class vanished", missing_class, "expected exactly"),
        ("empty scenario list", no_scenarios, "missing or empty"),
        ("duplicate scenario names", duplicate_scenarios, "duplicate"),
        ("negative count", negative_count, "count >= 0"),
        ("hung worker never evicted", no_eviction, "never evicted"),
        ("eviction before the budget", eviction_before_budget, "fired early"),
        ("eviction implausibly slow", eviction_too_slow, "50x"),
        ("stall budget is null", stall_budget_null, "stall_budget_ms"),
        ("negative fenced discards", fenced_negative, "count >= 0"),
        ("late completion never fenced", fenced_zero, "never fenced"),
        ("discards exceed requests", fenced_exceeds_requests, "> requests"),
        (
            "eviction without replacement",
            restarts_below_evictions,
            "never replaced",
        ),
        (
            "stall-eviction scenario missing",
            missing_eviction_scenario,
            "no 'stall-eviction' scenario",
        ),
        ("soak scenario missing", missing_soak_scenario, "no 'soak' scenario"),
        ("soak with zero rounds", soak_rounds_zero, "'rounds'"),
        ("soak wall clock is zero", soak_wall_clock_zero, "soak_seconds"),
    ]


def tune_mutations() -> list[tuple[str, object, str]]:
    """Mutations of the good tune_cache report; each must fail the
    warm-start contract check with the right attribution."""

    def warm_not_faster(d):
        d["warm_plan_ms"] = d["cold_plan_ms"] * 2

    def warm_measured(d):
        d["warm_measurements"] = 7

    def warm_missed(d):
        d["warm_misses"] = 3

    def cold_never_measured(d):
        d["cold_measurements"] = 0

    def no_entries(d):
        d["entries"] = 0

    def choices_diverged(d):
        d["choices_identical"] = False

    def roundtrip_broken(d):
        d["roundtrip_bit_identical"] = False

    def cold_time_null(d):
        # The JSON writer emits null for NaN/Inf — must be rejected.
        d["cold_plan_ms"] = None

    return [
        ("warm plan not faster than cold", warm_not_faster, "not faster"),
        ("warm plan measured", warm_measured, "warm_measurements"),
        ("warm plan fell through the cache", warm_missed, "warm_misses"),
        ("cold plan never measured", cold_never_measured, "cold_measurements"),
        ("empty cache", no_entries, "entries"),
        ("choices diverged", choices_diverged, "choices_identical"),
        ("round trip broken", roundtrip_broken, "roundtrip_bit_identical"),
        ("cold time is null", cold_time_null, "cold_plan_ms"),
    ]


def hotpath_mutations() -> list[tuple[str, object, str]]:
    """Mutations of the good hotpath_micro report; each must fail the
    blocked-layout contract check with the right attribution."""

    def blocked_bits_diverged(d):
        d["cuconv_blocked_vs_tiled"][1]["bit_identical"] = False

    def blocked_time_null(d):
        # The JSON writer emits null for NaN/Inf — must be rejected.
        d["cuconv_blocked_vs_tiled"][0]["blocked_p50_us"] = None

    def no_blocked_rows(d):
        d["cuconv_blocked_vs_tiled"] = []

    def blocked_row_unlabeled(d):
        del d["cuconv_blocked_vs_tiled"][0]["config"]

    def simd_level_missing(d):
        del d["simd_level"]

    def inverse_broken(d):
        # Someone edits one geomean field and forgets its twin: the
        # baseline metric would silently gate on a stale number.
        d["tiled_over_blocked"] = d["tiled_over_blocked"] * 2

    def inverse_null(d):
        d["tiled_over_blocked"] = None

    def sweep_truncated(d):
        d["tile_sweep"] = d["tile_sweep"][:2]

    return [
        ("blocked bit-identity false", blocked_bits_diverged, "bit_identical"),
        ("blocked time is null", blocked_time_null, "blocked_p50_us"),
        ("no blocked rows", no_blocked_rows, "missing or empty"),
        ("blocked row unlabeled", blocked_row_unlabeled, "missing 'config'"),
        ("simd level missing", simd_level_missing, "simd_level"),
        ("geomean/inverse mismatch", inverse_broken, "not the inverse"),
        ("inverse is null", inverse_null, "tiled_over_blocked"),
        ("tile sweep truncated", sweep_truncated, "candidate set"),
    ]


def baseline_gate_failures(tune_good: dict, tmpdir: str) -> list[str]:
    """Exercise --baseline: healthy report passes; a regressed report,
    a missing baseline, and a malformed tolerance each fail."""
    failures: list[str] = []

    rc, out = run_checker(
        tune_good, tmpdir, name="BENCH_tune.json", baseline_dir=BASELINES
    )
    if rc != 0:
        failures.append(f"good report rejected by committed baseline (rc={rc}):\n{out}")

    # A 10x slower warm plan is still faster than cold (passing the
    # plain checks) but blows the baseline's warm/cold tolerance — the
    # geomean gate must be what catches it.
    slow = copy.deepcopy(tune_good)
    slow["warm_plan_ms"] = tune_good["warm_plan_ms"] * 10
    rc, out = run_checker(slow, tmpdir, name="BENCH_tune.json", baseline_dir=BASELINES)
    if rc == 0:
        failures.append("regressed report passed the baseline gate")
    elif "geomean" not in out:
        failures.append(
            f"regressed report failed for the wrong reason (wanted 'geomean'):\n{out}"
        )

    empty_dir = os.path.join(tmpdir, "no_baselines")
    os.makedirs(empty_dir, exist_ok=True)
    rc, out = run_checker(
        tune_good, tmpdir, name="BENCH_tune.json", baseline_dir=empty_dir
    )
    if rc == 0:
        failures.append("missing baseline file was not caught")
    elif "no baseline" not in out:
        failures.append(
            f"missing baseline failed for the wrong reason (wanted 'no baseline'):\n{out}"
        )

    bad_dir = os.path.join(tmpdir, "bad_baselines")
    os.makedirs(bad_dir, exist_ok=True)
    with open(os.path.join(bad_dir, "BENCH_tune.json"), "w", encoding="utf-8") as f:
        json.dump(
            {"bench": "tune_cache", "tolerance": "fast", "metrics": {"warm_over_cold": 0.25}},
            f,
        )
    rc, out = run_checker(
        tune_good, tmpdir, name="BENCH_tune.json", baseline_dir=bad_dir
    )
    if rc == 0:
        failures.append("malformed baseline tolerance was not caught")
    elif "tolerance" not in out:
        failures.append(
            f"malformed tolerance failed for the wrong reason (wanted 'tolerance'):\n{out}"
        )

    return failures


def hotpath_baseline_failures(hotpath_good: dict, tmpdir: str) -> list[str]:
    """Exercise the hotpath baseline: the good report passes against
    the committed baseline, a blocked-layout slowdown fails the
    geomean gate."""
    failures: list[str] = []

    rc, out = run_checker(
        hotpath_good, tmpdir, name="BENCH_hotpath.json", baseline_dir=BASELINES
    )
    if rc != 0:
        failures.append(
            f"good hotpath report rejected by committed baseline (rc={rc}):\n{out}"
        )

    # Blocked 10x slower: both geomean fields move together (keeping
    # the plain inverse check green), so only the baseline gate can
    # catch the regression.
    slow = copy.deepcopy(hotpath_good)
    slow["tiled_over_blocked"] = hotpath_good["tiled_over_blocked"] * 10
    slow["blocked_geomean_speedup"] = hotpath_good["blocked_geomean_speedup"] / 10
    rc, out = run_checker(
        slow, tmpdir, name="BENCH_hotpath.json", baseline_dir=BASELINES
    )
    if rc == 0:
        failures.append("regressed hotpath report passed the baseline gate")
    elif "geomean" not in out:
        failures.append(
            f"regressed hotpath failed for the wrong reason (wanted 'geomean'):\n{out}"
        )

    return failures


def main() -> int:
    with open(GOOD, encoding="utf-8") as f:
        good = json.load(f)
    with open(TUNE_GOOD, encoding="utf-8") as f:
        tune_good = json.load(f)
    with open(HOTPATH_GOOD, encoding="utf-8") as f:
        hotpath_good = json.load(f)

    failures: list[str] = []
    with tempfile.TemporaryDirectory() as tmpdir:
        rc, out = run_checker(good, tmpdir)
        if rc != 0:
            failures.append(f"good chaos fixture rejected (rc={rc}):\n{out}")
        rc, out = run_checker(tune_good, tmpdir, name="BENCH_tune.json")
        if rc != 0:
            failures.append(f"good tune fixture rejected (rc={rc}):\n{out}")
        rc, out = run_checker(hotpath_good, tmpdir, name="BENCH_hotpath.json")
        if rc != 0:
            failures.append(f"good hotpath fixture rejected (rc={rc}):\n{out}")

        for name, mutate, expect in mutations():
            doc = copy.deepcopy(good)
            mutate(doc)
            rc, out = run_checker(doc, tmpdir)
            if rc == 0:
                failures.append(f"mutation '{name}' was not caught")
            elif expect not in out:
                failures.append(
                    f"mutation '{name}' failed for the wrong reason "
                    f"(wanted {expect!r} in output):\n{out}"
                )

        for name, mutate, expect in tune_mutations():
            doc = copy.deepcopy(tune_good)
            mutate(doc)
            rc, out = run_checker(doc, tmpdir, name="BENCH_tune.json")
            if rc == 0:
                failures.append(f"tune mutation '{name}' was not caught")
            elif expect not in out:
                failures.append(
                    f"tune mutation '{name}' failed for the wrong reason "
                    f"(wanted {expect!r} in output):\n{out}"
                )

        for name, mutate, expect in hotpath_mutations():
            doc = copy.deepcopy(hotpath_good)
            mutate(doc)
            rc, out = run_checker(doc, tmpdir, name="BENCH_hotpath.json")
            if rc == 0:
                failures.append(f"hotpath mutation '{name}' was not caught")
            elif expect not in out:
                failures.append(
                    f"hotpath mutation '{name}' failed for the wrong reason "
                    f"(wanted {expect!r} in output):\n{out}"
                )

        failures.extend(baseline_gate_failures(tune_good, tmpdir))
        failures.extend(hotpath_baseline_failures(hotpath_good, tmpdir))

    if failures:
        print(f"test_check_bench: {len(failures)} failure(s):")
        for f_ in failures:
            print(f"  FAIL {f_}")
        return 1
    n_mut = len(mutations()) + len(tune_mutations()) + len(hotpath_mutations())
    print(
        f"test_check_bench: 3 good fixtures + "
        f"{n_mut} mutations + baseline gates OK"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
