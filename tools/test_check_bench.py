#!/usr/bin/env python3
"""Self-test for tools/check_bench.py against known-good and mutated
chaos reports.

The chaos checker is itself part of the fault-tolerance contract: if it
silently accepted a report with lost requests or a skipped recovery,
the CI gate would be decorative. This script runs the checker on the
committed good fixture (must pass) and on a battery of single-field
mutations (each must fail, with the violation attributed to the right
field).

Usage:
    python3 tools/test_check_bench.py
"""

from __future__ import annotations

import copy
import json
import os
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
CHECKER = os.path.join(HERE, "check_bench.py")
GOOD = os.path.join(HERE, "fixtures", "BENCH_chaos_good.json")


def run_checker(doc: dict, tmpdir: str) -> tuple[int, str]:
    path = os.path.join(tmpdir, "BENCH_chaos.json")
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f)
    proc = subprocess.run(
        [sys.executable, CHECKER, path],
        capture_output=True,
        text=True,
        check=False,
    )
    return proc.returncode, proc.stdout + proc.stderr


def mutations() -> list[tuple[str, object, str]]:
    """(name, mutator, expected-substring-in-output) triples. Each
    mutator edits a deep copy of the good document in place."""

    def wrong_tag(d):
        d["bench"] = "chaos_srving"

    def scenario_lost(d):
        d["scenarios"][0]["lost"] = 2

    def class_lost(d):
        d["scenarios"][0]["classes"][1]["lost"] = 1

    def broken_accounting(d):
        d["scenarios"][1]["classes"][0]["completed"] -= 1

    def no_restarts(d):
        for s in d["scenarios"]:
            s["restarts"] = 0

    def bits_diverged(d):
        d["post_recovery_bit_identical"] = False

    def pool_not_restored(d):
        d["scenarios"][0]["pool_restored"] = False

    def recovery_nan(d):
        # The JSON writer emits null for NaN/Inf — must be rejected.
        d["scenarios"][0]["recovery_max_ms"] = None

    def frac_out_of_range(d):
        d["scenarios"][2]["shed_curve"][1]["batch_rejected_frac"] = 1.5

    def rejected_exceeds_offered(d):
        d["scenarios"][2]["shed_curve"][2]["batch_rejected"] = 99

    def missing_class(d):
        d["scenarios"][0]["classes"] = d["scenarios"][0]["classes"][:1]

    def no_scenarios(d):
        d["scenarios"] = []

    def duplicate_scenarios(d):
        d["scenarios"][1]["scenario"] = d["scenarios"][0]["scenario"]

    def negative_count(d):
        d["scenarios"][2]["classes"][1]["rejected"] = -3

    return [
        ("wrong bench tag", wrong_tag, "unknown bench tag"),
        ("scenario-level lost", scenario_lost, "zero-lost"),
        ("class-level lost", class_lost, "'lost'"),
        ("broken four-way accounting", broken_accounting, "offered"),
        ("no scenario restarted", no_restarts, "never exercised"),
        ("bit-identity flag false", bits_diverged, "post_recovery_bit_identical"),
        ("pool not restored", pool_not_restored, "pool_restored"),
        ("recovery time is null", recovery_nan, "recovery_max_ms"),
        ("shed frac out of range", frac_out_of_range, "outside [0, 1]"),
        ("rejected exceeds offered", rejected_exceeds_offered, "> offered"),
        ("a priority class vanished", missing_class, "expected exactly"),
        ("empty scenario list", no_scenarios, "missing or empty"),
        ("duplicate scenario names", duplicate_scenarios, "duplicate"),
        ("negative count", negative_count, "count >= 0"),
    ]


def main() -> int:
    with open(GOOD, encoding="utf-8") as f:
        good = json.load(f)

    failures: list[str] = []
    with tempfile.TemporaryDirectory() as tmpdir:
        rc, out = run_checker(good, tmpdir)
        if rc != 0:
            failures.append(f"good fixture rejected (rc={rc}):\n{out}")

        for name, mutate, expect in mutations():
            doc = copy.deepcopy(good)
            mutate(doc)
            rc, out = run_checker(doc, tmpdir)
            if rc == 0:
                failures.append(f"mutation '{name}' was not caught")
            elif expect not in out:
                failures.append(
                    f"mutation '{name}' failed for the wrong reason "
                    f"(wanted {expect!r} in output):\n{out}"
                )

    if failures:
        print(f"test_check_bench: {len(failures)} failure(s):")
        for f_ in failures:
            print(f"  FAIL {f_}")
        return 1
    print(f"test_check_bench: good fixture + {len(mutations())} mutations OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
